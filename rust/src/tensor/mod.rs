//! Host tensors + the artifact weight store (substrate S8).
//!
//! `Tensor` is the coordinator's host-side array: f32 data + shape, with
//! just the ops the serving path needs (row gather/scatter, slicing stacked
//! expert weights, elementwise combine). Heavy math belongs to the compiled
//! HLO artifacts, not here.

pub mod store;

pub use store::WeightStore;

/// A dense row-major f32 host tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Size of trailing dims after the first (row width for rank>=2).
    pub fn row_len(&self) -> usize {
        self.shape[1..].iter().product::<usize>().max(1)
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_len();
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let w = self.row_len();
        &mut self.data[i * w..(i + 1) * w]
    }

    /// View the i-th slice along axis 0 as its own tensor (copy).
    /// Used to slice per-expert weights out of stacked [E, ...] tensors.
    pub fn slice0(&self, i: usize) -> Tensor {
        assert!(self.rank() >= 2, "slice0 needs rank >= 2");
        assert!(i < self.shape[0], "slice0 index {i} out of {}", self.shape[0]);
        Tensor { shape: self.shape[1..].to_vec(), data: self.row(i).to_vec() }
    }

    /// Gather rows into a fixed-capacity tile, zero-padding the tail
    /// (the serverless expert invocation prologue).
    pub fn gather_rows_padded(&self, rows: &[usize], capacity: usize) -> Tensor {
        assert!(rows.len() <= capacity, "{} rows > capacity {capacity}", rows.len());
        let w = self.row_len();
        let mut out = Tensor::zeros(&[capacity, w]);
        for (slot, &r) in rows.iter().enumerate() {
            out.row_mut(slot).copy_from_slice(self.row(r));
        }
        out
    }

    /// out[rows[j]] += scale[j] * tile[j] — the weighted expert combine.
    pub fn scatter_add_scaled(&mut self, rows: &[usize], tile: &Tensor, scales: &[f32]) {
        assert_eq!(rows.len(), scales.len());
        let w = self.row_len();
        assert_eq!(tile.row_len(), w);
        for (j, (&r, &s)) in rows.iter().zip(scales).enumerate() {
            let dst = self.row_mut(r);
            let src = tile.row(j);
            for (d, x) in dst.iter_mut().zip(src) {
                *d += s * x;
            }
        }
    }

    /// Elementwise a + b (residual add).
    pub fn add(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn reshape(&self, shape: &[usize]) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.numel());
        Tensor { shape: shape.to_vec(), data: self.data.clone() }
    }

    /// Max |a - b| over all elements (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    pub fn argmax_row(&self, i: usize) -> usize {
        let row = self.row(i);
        crate::util::fail::expect_invariant(
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i),
            "argmax over a non-empty row",
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_rows() {
        let t = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert_eq!(t.row_len(), 3);
        assert_eq!(t.numel(), 6);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(&[2, 2], vec![1.0; 5]);
    }

    #[test]
    fn slice0_extracts_expert() {
        let t = Tensor::from_vec(&[2, 2, 2], (0..8).map(|x| x as f32).collect());
        let e1 = t.slice0(1);
        assert_eq!(e1.shape, vec![2, 2]);
        assert_eq!(e1.data, vec![4., 5., 6., 7.]);
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let t = Tensor::from_vec(&[4, 2], vec![1., 1., 2., 2., 3., 3., 4., 4.]);
        let tile = t.gather_rows_padded(&[2, 0], 3);
        assert_eq!(tile.shape, vec![3, 2]);
        assert_eq!(tile.row(0), &[3., 3.]);
        assert_eq!(tile.row(1), &[1., 1.]);
        assert_eq!(tile.row(2), &[0., 0.]); // pad

        let mut out = Tensor::zeros(&[4, 2]);
        out.scatter_add_scaled(&[2, 0], &tile, &[0.5, 2.0]);
        assert_eq!(out.row(2), &[1.5, 1.5]);
        assert_eq!(out.row(0), &[2.0, 2.0]);
        assert_eq!(out.row(1), &[0.0, 0.0]);
    }

    #[test]
    fn add_and_diff() {
        let a = Tensor::from_vec(&[2], vec![1., 2.]);
        let b = Tensor::from_vec(&[2], vec![3., 5.]);
        assert_eq!(a.add(&b).data, vec![4., 7.]);
        assert_eq!(a.max_abs_diff(&b), 3.0);
    }

    #[test]
    fn argmax() {
        let t = Tensor::from_vec(&[2, 3], vec![0., 5., 1., 9., 2., 3.]);
        assert_eq!(t.argmax_row(0), 1);
        assert_eq!(t.argmax_row(1), 0);
    }
}
