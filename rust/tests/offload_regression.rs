//! Deterministic regressions for the PR-10 expert-residency hierarchy:
//! predictor-driven prefetch must beat the demand-fetch ablation on tail
//! latency whenever expert HBM is oversubscribed, Oracle coverage must be
//! structurally total, and a disabled hierarchy must leave zero trace in
//! the report.
//!
//! Both duel arms replay the identical seeded trace through the identical
//! store (residency and eviction decisions depend only on the fetch call
//! sequence, never on the issue times), so every assertion here is exact
//! — no tolerance windows, no timing flake.

use moeless::baselines::PolicyKind;
use moeless::cluster::{Cluster, CostModel};
use moeless::config::{ClusterSpec, DatasetSpec, ModelSpec, MoelessParams};
use moeless::engine::{MoelessPolicy, Policy};
use moeless::metrics::SloSpec;
use moeless::predictor::OraclePredictor;
use moeless::sim::{run, SimConfig};
use moeless::workload::Scenario;

/// The HBM-oversubscribed duel fleet: half the expert set fits in HBM,
/// the rest spills to DRAM/NVMe.
fn oversubscribed(demand_fetch: bool) -> SimConfig {
    let mut cfg = SimConfig::new(
        ModelSpec::mixtral_8x7b(),
        DatasetSpec::lmsys(),
        PolicyKind::Moeless,
    );
    cfg.scenario = Scenario::bursty();
    cfg.duration_s = 15.0;
    cfg.base_rps = 6.0;
    cfg.seed = 9;
    cfg.params.expert_hbm_frac = 0.5;
    cfg.params.prefetch_lookahead = 2;
    cfg.params.demand_fetch = demand_fetch;
    cfg
}

#[test]
fn prefetch_beats_demand_fetch_on_p99_ttft_at_equal_goodput() {
    let pre = run(&oversubscribed(false));
    let dem = run(&oversubscribed(true));

    // Same trace, same drain: the duel compares fetch disciplines, not
    // admission behavior.
    assert_eq!(pre.completed_requests, dem.completed_requests);
    assert!(pre.completed_requests > 0);

    // Prefetch covered fetches (the predictor's support is live); the
    // ablation covered none and paid a stall on every non-resident pair.
    assert!(pre.prefetch_hits > 0, "prefetch arm must cover fetches");
    assert_eq!(dem.prefetch_hits, 0, "demand arm must cover nothing");
    assert!(dem.prefetch_misses > 0);
    assert!(dem.offload_stall_ms > 0.0, "demand fetches land on the critical path");

    // The tentpole claim: overlapping predicted fetches with earlier
    // layers' compute strictly cuts total stall, and the tail TTFT must
    // never be worse at equal goodput.
    assert!(
        pre.offload_stall_ms < dem.offload_stall_ms,
        "prefetch stall {:.1}ms must undercut demand stall {:.1}ms",
        pre.offload_stall_ms,
        dem.offload_stall_ms,
    );
    assert!(
        pre.ttft_sketch.p(99.0) <= dem.ttft_sketch.p(99.0),
        "prefetch p99 TTFT {:.1}ms must not exceed demand {:.1}ms",
        pre.ttft_sketch.p(99.0),
        dem.ttft_sketch.p(99.0),
    );
    let slo = SloSpec::default();
    assert!(pre.goodput_rps(&slo) >= dem.goodput_rps(&slo));

    // Both arms accrued residency in every tier of the oversubscribed
    // hierarchy.
    for r in [&pre, &dem] {
        assert!(r.hbm_residency_gb_s > 0.0);
        assert!(r.nvme_residency_gb_s > 0.0);
    }
}

#[test]
fn oracle_prefetch_yields_zero_miss_stalls() {
    // OraclePredictor's raw prediction equals the actual loads, so the
    // prefetch support covers every served expert — zero demand fetches,
    // however tight the HBM capacity. (The sub-threshold 0.3 load draws
    // no planned replica and is served through repair; it must still be
    // covered.)
    let model = ModelSpec::mixtral_8x7b();
    let spec = ClusterSpec::a6000_x8();
    let params = MoelessParams { expert_hbm_frac: 0.25, ..Default::default() };
    let mut p = MoelessPolicy::with_predictor(&model, &spec, params, Box::new(OraclePredictor));
    let cm = CostModel::new(&model, &spec);
    let mut cluster = Cluster::new(spec);
    let loads = vec![500.0, 0.3, 100.0, 100.0, 90.0, 80.0, 70.0, 60.0];
    for t in 0..6 {
        for layer in 0..4 {
            p.run_layer(layer, &loads, &mut cluster, &cm, t as f64);
        }
        p.end_iteration(&mut cluster, t as f64);
    }
    let stats = p.offload_stats().expect("store must be live at frac 0.25");
    assert_eq!(stats.prefetch_misses, 0, "oracle coverage must be total");
    assert!(stats.prefetch_hits > 0);
}

#[test]
fn infinite_fetch_bandwidth_eliminates_stalls_exactly() {
    // With free transfers every fetch completes at its start instant, so
    // the (done - now).max(0) stall is exactly 0.0 — pins that the store
    // never manufactures stall out of bookkeeping alone.
    let model = ModelSpec::mixtral_8x7b();
    let mut spec = ClusterSpec::a6000_x8();
    for g in &mut spec.gpus {
        g.dram_gbps = f64::INFINITY;
        g.nvme_gbps = f64::INFINITY;
    }
    let params = MoelessParams { expert_hbm_frac: 0.25, ..Default::default() };
    let mut p = MoelessPolicy::with_predictor(&model, &spec, params, Box::new(OraclePredictor));
    let cm = CostModel::new(&model, &spec);
    let mut cluster = Cluster::new(spec);
    let loads = vec![500.0, 200.0, 100.0, 100.0, 90.0, 80.0, 70.0, 60.0];
    for t in 0..4 {
        for layer in 0..4 {
            p.run_layer(layer, &loads, &mut cluster, &cm, t as f64);
        }
        p.end_iteration(&mut cluster, t as f64);
    }
    let stats = p.offload_stats().expect("store must be live");
    assert!(stats.prefetch_hits > 0);
    assert_eq!(stats.stall_ms, 0.0, "free transfers must never stall");
}

#[test]
fn disabled_hierarchy_reports_zero_offload_signals() {
    // expert_hbm_frac = 1.0 (the default) never builds the store: the
    // run must be the pre-PR-10 path with every offload field at its
    // zero default.
    let mut cfg = oversubscribed(false);
    cfg.params.expert_hbm_frac = 1.0;
    let r = run(&cfg);
    assert!(r.completed_requests > 0);
    assert_eq!(r.prefetch_hits, 0);
    assert_eq!(r.prefetch_misses, 0);
    assert_eq!(r.offload_stall_ms, 0.0);
    assert_eq!(r.offload_stall_p99_ms, 0.0);
    assert_eq!(r.hbm_residency_gb_s, 0.0);
    assert_eq!(r.dram_residency_gb_s, 0.0);
    assert_eq!(r.nvme_residency_gb_s, 0.0);
}
