//! Deterministic multi-model colocation regression: on a Zipf-skewed
//! 20-model catalog sharing one A6000 fleet, the start-time-optimized
//! (locality-aware) placement must beat the locality-oblivious baseline on
//! cold-start p99 AND per-model goodput — same seed, same trace, the only
//! difference is the placement score.
//!
//! The workload is sized so the gap is structural, not marginal: 20 × 8 GB
//! checkpoints (160 GB) fit the fleet's 384 GB HBM collectively, so the
//! locality policy converges to every model warm-resident somewhere, while
//! the oblivious policy keeps scattering models onto whichever device has
//! the shortest queue and pays the NVMe/DRAM reload (1.92 s / 0.32 s on
//! this hardware) over and over. The DRAM cache is deliberately too small
//! (32 GB = 4 checkpoints) to bail it out.

use moeless::config::{ClusterSpec, DatasetSpec, ModelSpec};
use moeless::metrics::RunReport;
use moeless::sim::multimodel::{run_multimodel, MmConfig};
use moeless::workload::{CatalogEntry, ModelCatalog, Scenario};

const N_MODELS: usize = 20;
const MODEL_GB: f64 = 8.0;
const SKEW: f64 = 1.2;

/// Explicit catalog: 20 equally-sized 8 GB models, rank-Zipf popularity.
/// Hand-built (not `ModelCatalog::zipf`) so the regression's geometry —
/// every checkpoint the same size, weights a pure rank law — is pinned in
/// the test itself.
fn catalog() -> ModelCatalog {
    let entries = (0..N_MODELS)
        .map(|i| {
            let base = ModelSpec::mixtral_8x7b();
            let scale = MODEL_GB / base.total_model_gb();
            CatalogEntry {
                model: ModelSpec {
                    name: format!("reg-{i:02}"),
                    expert_mem_gb: base.expert_mem_gb * scale,
                    misc_mem_gb: base.misc_mem_gb * scale,
                    ..base
                },
                weight: 1.0 / ((i + 1) as f64).powf(SKEW),
            }
        })
        .collect();
    ModelCatalog { entries }
}

fn run(locality: bool) -> RunReport {
    let mut cfg = MmConfig::new(catalog(), DatasetSpec::lmsys());
    let mut cluster = ClusterSpec::a6000_x8();
    // Small host cache: only ~4 checkpoints stay DRAM-warm, so evicted or
    // never-staged models pay the full NVMe path.
    cluster.dram_cache_gb = 32.0;
    cfg.cluster = cluster;
    cfg.scenario = Scenario::poisson();
    cfg.duration_s = 600.0;
    cfg.base_rps = 12.0;
    cfg.seed = 20_008;
    cfg.locality = locality;
    run_multimodel(&cfg)
}

#[test]
fn locality_beats_oblivious_on_cold_p99_and_goodput() {
    let loc = run(true);
    let obl = run(false);

    // Same trace on both sides: the catalogs, seed and arrival process are
    // identical, so every lane saw the same offered load.
    assert_eq!(loc.per_model.len(), N_MODELS);
    assert_eq!(obl.per_model.len(), N_MODELS);
    for (a, b) in loc.per_model.iter().zip(&obl.per_model) {
        assert_eq!(a.model, b.model);
        assert_eq!(a.arrivals, b.arrivals, "{}: offered load must match", a.model);
    }

    // Headline A: cold-start p99 across all served arrivals. The locality
    // policy reloads each checkpoint a handful of times (its p99 is the
    // warm zero); the oblivious policy keeps paying the tiered reload.
    assert!(
        loc.cold_p99_ms() < obl.cold_p99_ms(),
        "cold p99: locality {:.0}ms must beat oblivious {:.0}ms",
        loc.cold_p99_ms(),
        obl.cold_p99_ms()
    );

    // Headline B: aggregate per-model goodput (SLO-good requests per
    // simulated second, summed over lanes).
    assert!(
        loc.lanes_goodput_rps() > obl.lanes_goodput_rps(),
        "goodput: locality {:.2} req/s must beat oblivious {:.2} req/s",
        loc.lanes_goodput_rps(),
        obl.lanes_goodput_rps()
    );

    // The Zipf tail is where colocation policies go to die: the unpopular
    // half must also be served better, not sacrificed for the head.
    let tail_good = |r: &RunReport| -> u64 {
        r.per_model[N_MODELS / 2..].iter().map(|l| l.slo_good).sum()
    };
    assert!(
        tail_good(&loc) > tail_good(&obl),
        "unpopular-half goodput: locality {} must beat oblivious {}",
        tail_good(&loc),
        tail_good(&obl)
    );

    // Reload volume itself: locality converges to warm residency (its
    // colds are on the order of one first-touch per model), oblivious
    // churns — require at least a 3x gap so drift can't nibble this green.
    assert!(
        loc.cold_starts * 3 < obl.cold_starts,
        "cold starts: locality {} vs oblivious {} (need >3x gap)",
        loc.cold_starts,
        obl.cold_starts
    );

    // And the run is a regression fixture, not a flake: bit-identical on
    // repeat.
    let again = run(true);
    assert_eq!(loc.requests, again.requests);
    assert_eq!(loc.per_model, again.per_model);
    assert_eq!(loc.dollar_cost.to_bits(), again.dollar_cost.to_bits());
}
