//! Property-based tests on coordinator invariants (deliverable (c)):
//! routing, scaling, placement, batching and state-management laws that
//! must hold for *any* input, via the in-tree mini-proptest (util S7).

use moeless::cluster::Cluster;
use moeless::config::ClusterSpec;
use moeless::placer::Placer;
use moeless::predictor::accuracy::{l1_error, topk_overlap};
use moeless::predictor::blend_to_accuracy;
use moeless::router::{BatchLimits, Batcher};
use moeless::scaler::Scaler;
use moeless::serverless::FunctionManager;
use moeless::util::quickcheck::property;
use moeless::util::rng::Pcg;
use moeless::util::stats::cv;
use moeless::workload::TraceRequest;

// ---------------------------------------------------------------------------
// Scaler (Algorithm 1) invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_scaler_respects_cap_and_floor() {
    property(200, |g| {
        let n = g.usize_in(1, 32);
        let loads = g.loads(n, 2000.0);
        let cap = g.usize_in(1, 64);
        let v = g.f64_in(0.0, 1.0);
        let plan = Scaler::new(v, cap).scale(&loads);
        let active = loads.iter().filter(|&&w| w > 0.0).count();
        // Every loaded expert has >= 1 replica (no starvation), zero-load
        // experts have none (scale-to-zero), and the cap holds whenever it
        // admits all active experts.
        for (e, &w) in loads.iter().enumerate() {
            if w > 0.0 {
                assert!(plan.replicas[e] >= 1);
            } else {
                assert_eq!(plan.replicas[e], 0);
            }
        }
        assert!(plan.total() <= cap.max(active));
    });
}

#[test]
fn prop_scaler_never_increases_straggler() {
    property(200, |g| {
        let n = g.usize_in(1, 16);
        let loads = g.loads(n, 1000.0);
        let plan = Scaler::new(g.f64_in(0.0, 0.5), g.usize_in(n, 64)).scale(&loads);
        let before = loads.iter().cloned().fold(0.0, f64::max);
        assert!(plan.max_per_replica(&loads) <= before + 1e-9);
    });
}

#[test]
fn prop_scaler_incremental_cv_matches_recompute() {
    // The scaler maintains the per-replica-load CV incrementally (sum +
    // sum-of-squares). Verify the incremental identity against a
    // from-scratch `stats::cv` recomputation on the plan it returns, and
    // that the stop condition is consistent with that CV.
    property(200, |g| {
        let n = g.usize_in(1, 24);
        let loads = g.loads(n, 1500.0);
        let v = g.f64_in(0.05, 0.8);
        let cap = g.usize_in(1, 96);
        let plan = Scaler::new(v, cap).scale(&loads);
        let per = plan.per_replica_loads(&loads);
        if per.is_empty() {
            return;
        }
        let k = per.len() as f64;
        let sum: f64 = per.iter().sum();
        let sumsq: f64 = per.iter().map(|x| x * x).sum();
        let mean = sum / k;
        let incremental = if mean.abs() < 1e-12 {
            0.0
        } else {
            (sumsq / k - mean * mean).max(0.0).sqrt() / mean
        };
        let scratch = cv(&per);
        assert!(
            (incremental - scratch).abs() < 1e-6 * (1.0 + scratch),
            "incremental CV {incremental} vs from-scratch {scratch}"
        );
        // Stop condition: the CV target was met, or the cap bound.
        assert!(
            scratch <= v + 1e-6 || plan.total() >= cap,
            "CV {scratch} > {v} with {}/{cap} slots",
            plan.total()
        );
    });
}

#[test]
fn prop_scaler_meets_cv_or_exhausts_cap() {
    property(150, |g| {
        let n = g.usize_in(2, 16);
        let loads = g.loads(n, 500.0);
        if loads.iter().all(|&w| w == 0.0) {
            return;
        }
        let v = g.f64_in(0.1, 1.0);
        let cap = g.usize_in(2 * n, 4 * n);
        let plan = Scaler::new(v, cap).scale(&loads);
        let achieved = cv(&plan.per_replica_loads(&loads));
        assert!(
            achieved <= v + 1e-9 || plan.total() == cap,
            "CV {achieved} > {v} with {}/{} slots",
            plan.total(),
            cap
        );
    });
}

// ---------------------------------------------------------------------------
// Placer (Algorithm 2) invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_placer_places_every_replica_exactly_once() {
    property(200, |g| {
        let n = g.usize_in(1, 16);
        let loads = g.loads(n, 800.0);
        let replicas: Vec<usize> =
            loads.iter().map(|&w| if w > 0.0 { g.usize_in(1, 4) } else { 0 }).collect();
        let n_gpus = g.usize_in(1, 8);
        let cluster = Cluster::new(ClusterSpec::a6000_x8().with_n_gpus(n_gpus));
        let mut prev: Vec<Vec<usize>> = (0..n)
            .map(|_| g.vec_of(0, 2, |g| g.usize_in(0, n_gpus - 1)))
            .collect();
        let plan = Placer.place(&replicas, &loads, &mut prev, &cluster, 0.33);
        assert_eq!(plan.placements.len(), replicas.iter().sum::<usize>());
        for p in &plan.placements {
            assert!(p.gpu < n_gpus);
            assert!(p.load >= 0.0);
        }
        // Load conservation: placed load == total load of replicated experts.
        let placed: f64 = plan.placements.iter().map(|p| p.load).sum();
        let expected: f64 = loads
            .iter()
            .zip(&replicas)
            .filter(|(_, &r)| r > 0)
            .map(|(&w, _)| w)
            .sum();
        assert!((placed - expected).abs() < 1e-6);
    });
}

#[test]
fn prop_placer_balance_not_catastrophic() {
    // JSQ/LPT guarantee: max GPU load <= total/G + max single replica load.
    property(150, |g| {
        let n = g.usize_in(1, 16);
        let loads = g.loads(n, 800.0);
        let replicas: Vec<usize> = loads.iter().map(|&w| usize::from(w > 0.0)).collect();
        let n_gpus = g.usize_in(1, 8);
        let cluster = Cluster::new(ClusterSpec::a6000_x8().with_n_gpus(n_gpus));
        let mut prev = vec![Vec::new(); n];
        let plan = Placer.place(&replicas, &loads, &mut prev, &cluster, 0.33);
        let total: f64 = loads.iter().sum();
        let max_single = loads.iter().cloned().fold(0.0, f64::max);
        let bound = total / n_gpus as f64 + max_single + 1e-9;
        assert!(plan.max_gpu_load(n_gpus) <= bound);
    });
}

#[test]
fn prop_placer_warm_reuse_monotone() {
    // With previous instances for every expert, at least min(replicas,
    // previous) placements are reused.
    property(100, |g| {
        let n = g.usize_in(1, 8);
        let loads: Vec<f64> = (0..n).map(|_| g.f64_in(1.0, 100.0)).collect();
        let replicas = vec![1usize; n];
        let n_gpus = 4;
        let cluster = Cluster::new(ClusterSpec::a6000_x8().with_n_gpus(n_gpus));
        let mut prev: Vec<Vec<usize>> = (0..n).map(|e| vec![e % n_gpus]).collect();
        let plan = Placer.place(&replicas, &loads, &mut prev, &cluster, 0.33);
        assert_eq!(plan.reused_count(), n, "all single replicas reuse their old home");
    });
}

#[test]
fn placer_fallback_records_eviction_debt() {
    // A fully memory-exhausted cluster still places every replica, but each
    // placement owes the serverless manager one eviction.
    let mut cluster = Cluster::new(ClusterSpec::a6000_x8().with_n_gpus(2));
    assert!(cluster.reserve(0, 48.0));
    assert!(cluster.reserve(1, 48.0));
    let mut prev = vec![Vec::new(); 3];
    let plan = Placer.place(&[1, 1, 1], &[30.0, 20.0, 10.0], &mut prev, &cluster, 0.33);
    assert_eq!(plan.placements.len(), 3);
    assert_eq!(plan.evictions_owed, 3);
    assert!(plan.placements.iter().all(|p| p.gpu < 2));
}

#[test]
fn placer_partial_room_owes_only_the_overflow() {
    // One free slot on a 2-GPU cluster: the first replica fits, the second
    // owes an eviction.
    let spec = ClusterSpec::a6000_x8().with_n_gpus(2).with_mem_per_gpu(1.0);
    let mut cluster = Cluster::new(spec);
    assert!(cluster.reserve(0, 1.0));
    assert!(cluster.reserve(1, 0.5)); // 0.5 GB free on GPU 1: one 0.4 GB slot
    let mut prev = vec![Vec::new(); 2];
    let plan = Placer.place(&[1, 1], &[50.0, 40.0], &mut prev, &cluster, 0.4);
    assert_eq!(plan.placements.len(), 2);
    assert_eq!(plan.evictions_owed, 1);
}

/// Random heterogeneous fleet: 1-6 devices with independently drawn
/// memory, speed and bandwidth.
fn random_hetero_spec(g: &mut moeless::util::quickcheck::Gen) -> ClusterSpec {
    use moeless::config::GpuSpec;
    let n = g.usize_in(1, 6);
    let mut spec = ClusterSpec::a6000_x8().with_n_gpus(n);
    for d in &mut spec.gpus {
        *d = GpuSpec {
            name: "rand".into(),
            mem_gb: g.f64_in(0.5, 96.0),
            tflops: g.f64_in(50.0, 1200.0),
            hbm_gbps: g.f64_in(100.0, 4000.0),
            cost_per_hour: g.f64_in(0.1, 5.0),
            nvme_gbps: g.f64_in(1.0, 10.0),
            dram_gbps: g.f64_in(8.0, 64.0),
        };
    }
    spec
}

#[test]
fn prop_hetero_placer_never_exceeds_device_memory() {
    // For any mixed fleet and any replica plan: as long as the placer did
    // not have to fall back to eviction debt, the *new* (non-reused)
    // instances it assigns to a device always fit that device's own
    // remaining memory.
    property(150, |g| {
        let spec = random_hetero_spec(g);
        let n_gpus = spec.gpus.len();
        let free: Vec<f64> = spec.gpus.iter().map(|d| d.mem_gb).collect();
        let cluster = Cluster::new(spec);
        let n = g.usize_in(1, 12);
        let loads = g.loads(n, 900.0);
        let replicas: Vec<usize> =
            loads.iter().map(|&w| if w > 0.0 { g.usize_in(1, 3) } else { 0 }).collect();
        let expert_mem = g.f64_in(0.05, 2.0);
        let mut prev = vec![Vec::new(); n];
        let plan = Placer.place(&replicas, &loads, &mut prev, &cluster, expert_mem);
        assert_eq!(plan.placements.len(), replicas.iter().sum::<usize>());
        if plan.evictions_owed == 0 {
            let mut used = vec![0.0f64; n_gpus];
            for p in &plan.placements {
                used[p.gpu] += expert_mem;
            }
            for (gpu, (&u, &f)) in used.iter().zip(&free).enumerate() {
                assert!(u <= f + 1e-6, "gpu {gpu}: placed {u} GB > capacity {f} GB");
            }
        }
    });
}

#[test]
fn prop_hetero_placer_time_balance_bound() {
    // Greedy completion-time balancing on unrelated-speed machines (no
    // memory pressure): the per-GPU wall-clock makespan is bounded by the
    // perfectly-split time plus one worst item on the slowest device —
    // the standard list-scheduling guarantee, generalized by speeds.
    property(150, |g| {
        let mut spec = random_hetero_spec(g);
        for d in &mut spec.gpus {
            d.mem_gb = 512.0; // no memory pressure: pure balancing
        }
        let speeds: Vec<f64> = spec.gpus.iter().map(|d| d.tflops / 155.0).collect();
        let n_gpus = speeds.len();
        let cluster = Cluster::new(spec);
        let n = g.usize_in(1, 12);
        let loads = g.loads(n, 800.0);
        let replicas: Vec<usize> = loads.iter().map(|&w| usize::from(w > 0.0)).collect();
        let mut prev = vec![Vec::new(); n];
        let plan = Placer.place(&replicas, &loads, &mut prev, &cluster, 0.33);
        let tokens = plan.gpu_loads(n_gpus);
        let max_time = tokens
            .iter()
            .zip(&speeds)
            .map(|(&t, &s)| t / s)
            .fold(0.0, f64::max);
        let total: f64 = loads.iter().sum();
        let total_speed: f64 = speeds.iter().sum();
        let min_speed = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let max_single = loads.iter().cloned().fold(0.0, f64::max);
        let bound = total / total_speed + max_single / min_speed + 1e-6;
        assert!(max_time <= bound, "makespan {max_time} > bound {bound}");
    });
}

#[test]
fn prop_hetero_capacity_aware_never_loses_beyond_one_item_of_slack() {
    // Comparative guarantee on any speed-skewed, memory-rich fleet: the
    // capacity-aware plan's wall-clock makespan never exceeds the
    // token-balanced ablation's makespan (evaluated on the same real
    // speeds) by more than one worst item on the slowest device. Proof
    // sketch: the capacity-aware greedy is bounded by
    // total/Σspeeds + max_item/min_speed, while *no* assignment — the
    // token-balanced one included — can beat total/Σspeeds.
    property(150, |g| {
        use moeless::config::GpuSpec;
        let slow = g.usize_in(1, 5);
        let mut spec = ClusterSpec::a6000_x8().with_n_gpus(slow + 1).with_mem_per_gpu(512.0);
        let ratio = g.f64_in(2.0, 8.0);
        spec.gpus[0] = GpuSpec {
            name: "fast".into(),
            tflops: 155.0 * ratio,
            mem_gb: 512.0,
            ..GpuSpec::a6000()
        };
        let speeds: Vec<f64> = spec.gpus.iter().map(|d| d.tflops / 155.0).collect();
        let n_gpus = speeds.len();
        let mut token_spec = spec.clone();
        token_spec.capacity_aware = false;
        let (aware, token) = (Cluster::new(spec), Cluster::new(token_spec));

        let n = g.usize_in(1, 10);
        let loads = g.loads(n, 600.0);
        if loads.iter().all(|&w| w == 0.0) {
            return;
        }
        let replicas: Vec<usize> = loads.iter().map(|&w| usize::from(w > 0.0)).collect();
        let makespan = |cluster: &Cluster| {
            let mut prev = vec![Vec::new(); n];
            let plan = Placer.place(&replicas, &loads, &mut prev, cluster, 0.33);
            plan.gpu_loads(n_gpus)
                .iter()
                .zip(&speeds)
                .map(|(&t, &s)| t / s)
                .fold(0.0, f64::max)
        };
        let max_single = loads.iter().cloned().fold(0.0, f64::max);
        let min_speed = speeds.iter().cloned().fold(f64::INFINITY, f64::min);
        let slack = max_single / min_speed + 1e-6;
        let (a, t) = (makespan(&aware), makespan(&token));
        assert!(a <= t + slack, "aware makespan {a} > token-balanced {t} + slack {slack}");
    });
}

#[test]
fn placer_consumes_warm_candidates_in_place() {
    // Warm candidates are consumed as they are reused — each live instance
    // backs at most one replica, and leftovers stay for the caller.
    let cluster = Cluster::new(ClusterSpec::a6000_x8());
    let mut prev = vec![vec![3, 5], vec![1]];
    let plan = Placer.place(&[1, 1], &[60.0, 30.0], &mut prev, &cluster, 0.33);
    assert_eq!(plan.reused_count(), 2);
    // Expert 0 used one of its two candidates; expert 1 used its only one.
    assert_eq!(prev[0].len(), 1);
    assert!(prev[1].is_empty());
    let e0 = plan.placements.iter().find(|p| p.expert == 0).unwrap();
    assert!(!prev[0].contains(&e0.gpu), "the reused candidate was removed");
}

// ---------------------------------------------------------------------------
// Serverless manager invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_manager_memory_conservation() {
    property(60, |g| {
        let spec = ClusterSpec::a6000_x8();
        let mut cluster = Cluster::new(spec);
        let mut fm = FunctionManager::new(0.33, g.f64_in(0.5, 20.0), 45.0, 4, 8, 8);
        let steps = g.usize_in(1, 40);
        for t in 0..steps {
            let n_place = g.usize_in(0, 12);
            let placement: Vec<(usize, usize)> =
                (0..n_place).map(|_| (g.usize_in(0, 7), g.usize_in(0, 7))).collect();
            fm.apply_layer(&mut cluster, g.usize_in(0, 3), &placement, t as f64);
            if g.bool() {
                fm.reap(&mut cluster, t as f64);
            }
            // Memory accounting is consistent at every step.
            let used = cluster.total_mem_used_gb();
            let expect = fm.live_count() as f64 * 0.33;
            assert!((used - expect).abs() < 1e-6, "used {used} vs {expect}");
        }
        fm.drain(&mut cluster, steps as f64);
        assert_eq!(fm.live_count(), 0);
        assert!(cluster.total_mem_used_gb().abs() < 1e-9);
    });
}

// ---------------------------------------------------------------------------
// Router invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_batcher_conserves_requests_and_tokens() {
    property(100, |g| {
        let n = g.usize_in(0, 40);
        let mut reqs = Vec::new();
        for i in 0..n {
            reqs.push(TraceRequest {
                id: i as u64,
                arrival_s: g.f64_in(0.0, 10.0),
                prompt_tokens: g.usize_in(1, 300),
                output_tokens: g.usize_in(1, 30),
            });
        }
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let total_prompt: u64 = reqs.iter().map(|r| r.prompt_tokens as u64).sum();
        let total_out: u64 = reqs.iter().map(|r| r.output_tokens as u64).sum();

        let mut b = Batcher::new();
        b.enqueue(&reqs);
        let mut clock = 0.0;
        let mut guard = 0;
        while !b.idle() {
            match b.next_iteration(clock) {
                Some(_) => b.complete_iteration(clock),
                None => clock = b.next_arrival().unwrap_or(clock + 1.0),
            }
            clock += 0.05;
            guard += 1;
            assert!(guard < 100_000, "batcher must terminate");
        }
        assert_eq!(b.admitted, n as u64);
        assert_eq!(b.completed, n as u64);
        assert_eq!(b.tokens_prefilled, total_prompt);
        // Every output token is either the prefill's first token or a
        // decode step: decoded == total_out - n.
        assert_eq!(b.tokens_decoded, total_out - n as u64);
    });
}

#[test]
fn prop_kv_occupancy_and_accounting_invariants() {
    // KV-gated batcher laws, for any workload and any budget:
    //  (a) KV occupancy never exceeds the budget after any
    //      next_iteration / complete_iteration sequence;
    //  (b) no request is ever lost: admitted = in-flight + requeued +
    //      finished at every step, and admitted + rejected = enqueued at
    //      drain;
    //  (c) token progress is monotone across preemption, and every
    //      resumed request recomputed at least its full prompt.
    property(80, |g| {
        let budget_tokens = g.usize_in(16, 400);
        let cap = if g.bool() { g.usize_in(8, 256) } else { 0 };
        let mut b = Batcher::with_limits(BatchLimits {
            max_batch_tokens: cap,
            kv_budget_bytes: budget_tokens as f64,
            kv_bytes_per_token: 1.0,
            prefill_chunk_tokens: 0,
        });
        let n = g.usize_in(1, 30);
        let mut reqs = Vec::new();
        for i in 0..n {
            reqs.push(TraceRequest {
                id: i as u64,
                arrival_s: g.f64_in(0.0, 5.0),
                prompt_tokens: g.usize_in(1, 80),
                output_tokens: g.usize_in(1, 25),
            });
        }
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let infeasible = reqs
            .iter()
            .filter(|r| r.prompt_tokens + r.output_tokens > budget_tokens)
            .count() as u64;
        b.enqueue(&reqs);

        let mut clock = 0.0f64;
        let mut progress = vec![0usize; n];
        let mut guard = 0;
        while !b.idle() {
            match b.next_iteration(clock) {
                Some(_) => b.complete_iteration(clock + 0.02),
                // A `None` may have *rejected* the tail of the queue and
                // gone idle in the same call — no arrival need exist.
                None => clock = b.next_arrival().unwrap_or(clock).max(clock),
            }
            clock += 0.05;
            assert!(
                b.kv_bytes_in_use() <= budget_tokens as f64 + 1e-9,
                "occupancy {} over budget {budget_tokens}",
                b.kv_bytes_in_use()
            );
            assert_eq!(
                b.admitted as usize,
                b.in_flight() + b.requeued_len() + b.finished.len(),
                "an admitted request went missing"
            );
            for r in &reqs {
                if let Some(p) = b.progress_of(r.id) {
                    let seen = &mut progress[r.id as usize];
                    assert!(p >= *seen, "id {}: progress {p} < {}", r.id, *seen);
                    *seen = p;
                }
            }
            guard += 1;
            assert!(guard < 500_000, "batcher must drain");
        }

        assert_eq!(b.admitted + b.rejected, n as u64);
        assert_eq!(b.rejected, infeasible);
        assert_eq!(b.completed, b.admitted);
        assert_eq!(b.resumes, b.preemptions, "every preemption resumed by drain");
        // Each resume recomputes the prompt plus >= 1 emitted token.
        let owed: u64 = b
            .finished
            .iter()
            .map(|r| r.preemptions as u64 * (r.prompt_tokens as u64 + 1))
            .sum();
        assert!(b.tokens_recomputed >= owed, "{} < {owed}", b.tokens_recomputed);
        for r in &b.finished {
            assert_eq!(progress[r.id as usize], r.output_tokens, "full output emitted");
        }
    });
}

#[test]
fn prop_chunked_prefill_conservation() {
    // Chunked-prefill laws, for any chunk budget, token cap and KV budget:
    //  (a) the sum of a request's first-time chunk tokens equals its
    //      prompt (conservation — also pinned by a debug_assert at
    //      retirement), and every request used at least
    //      ceil(prompt / chunk) chunks;
    //  (b) KV occupancy never exceeds the budget mid-chunk;
    //  (c) progress stays monotone when preemption lands between chunks,
    //      and landed prefill never exceeds its target.
    property(60, |g| {
        let chunk = g.usize_in(1, 64);
        let budget_tokens = g.usize_in(32, 400);
        let cap = if g.bool() { g.usize_in(16, 128) } else { 0 };
        let mut b = Batcher::with_limits(BatchLimits {
            max_batch_tokens: cap,
            kv_budget_bytes: budget_tokens as f64,
            kv_bytes_per_token: 1.0,
            prefill_chunk_tokens: chunk,
        });
        let n = g.usize_in(1, 25);
        let mut reqs = Vec::new();
        for i in 0..n {
            reqs.push(TraceRequest {
                id: i as u64,
                arrival_s: g.f64_in(0.0, 5.0),
                prompt_tokens: g.usize_in(1, 120),
                output_tokens: g.usize_in(1, 20),
            });
        }
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let feasible_prompt: u64 = reqs
            .iter()
            .filter(|r| r.prompt_tokens + r.output_tokens <= budget_tokens)
            .map(|r| r.prompt_tokens as u64)
            .sum();
        b.enqueue(&reqs);

        let mut clock = 0.0f64;
        let mut progress = vec![0usize; n];
        let mut guard = 0;
        while !b.idle() {
            match b.next_iteration(clock) {
                Some(_) => b.complete_iteration(clock + 0.02),
                None => clock = b.next_arrival().unwrap_or(clock).max(clock),
            }
            clock += 0.05;
            // (b) mid-chunk occupancy respects the budget.
            assert!(
                b.kv_bytes_in_use() <= budget_tokens as f64 + 1e-9,
                "occupancy {} over budget {budget_tokens}",
                b.kv_bytes_in_use()
            );
            // (c) monotone output progress; landed prefill <= target.
            for r in &reqs {
                if let Some(p) = b.progress_of(r.id) {
                    let seen = &mut progress[r.id as usize];
                    assert!(p >= *seen, "id {}: progress {p} < {}", r.id, *seen);
                    *seen = p;
                }
                if let Some((landed, target)) = b.prefill_progress_of(r.id) {
                    assert!(landed <= target, "id {}: {landed} > {target}", r.id);
                }
            }
            guard += 1;
            assert!(guard < 500_000, "chunked batcher must drain");
        }

        // (a) conservation at drain: first-time prefill tokens equal the
        // admitted prompts exactly — recompute is ledgered separately —
        // and chunk counts are bounded below by the chunk budget.
        assert_eq!(b.tokens_prefilled, feasible_prompt);
        assert_eq!(b.completed, b.admitted);
        assert_eq!(b.resumes, b.preemptions);
        for r in &b.finished {
            assert_eq!(progress[r.id as usize], r.output_tokens);
            let min_chunks = r.prompt_tokens.div_ceil(chunk) as u32;
            assert!(
                r.chunks >= min_chunks,
                "id {}: {} chunks < ceil({}/{chunk})",
                r.id,
                r.chunks,
                r.prompt_tokens
            );
            if r.preemptions == 0 && b.preemptions == 0 {
                // Without churn anywhere, recompute never touches this run.
                assert_eq!(b.tokens_recomputed, 0);
            }
        }
    });
}

// ---------------------------------------------------------------------------
// SoA sequence arena (PR 9) laws.
// ---------------------------------------------------------------------------

#[test]
fn prop_arena_slot_reuse_never_aliases_live_sequences() {
    // For any interleaving of allocations and releases: a reused slot
    // never collides with a live sequence, every column of a fresh slot
    // carries the new occupant's values (nothing leaks from the previous
    // tenant), and capacity equals the peak number of simultaneously live
    // sequences — the O(in-flight) memory bound.
    use moeless::router::arena::{SeqArena, SeqSeed};
    use std::collections::BTreeMap;
    property(150, |g| {
        let mut arena = SeqArena::default();
        let mut live: BTreeMap<u32, u64> = BTreeMap::new();
        let mut next_id = 0u64;
        let mut peak = 0usize;
        for _ in 0..g.usize_in(1, 200) {
            if live.is_empty() || g.bool() {
                let seed = SeqSeed {
                    id: next_id,
                    arrival_s: g.f64_in(0.0, 50.0),
                    prompt_tokens: g.usize_in(1, 64),
                    output_tokens: g.usize_in(1, 16),
                };
                next_id += 1;
                let slot = arena.alloc(seed);
                assert!(!live.contains_key(&slot), "slot {slot} aliased a live sequence");
                assert!(arena.is_live(slot));
                assert_eq!(arena.id_of(slot), seed.id);
                assert_eq!(arena.kv_tokens_of(slot), 0, "reused slot leaked KV");
                assert_eq!(arena.remaining_out_of(slot), seed.output_tokens);
                assert_eq!(arena.prompt_tokens_of(slot), seed.prompt_tokens);
                assert_eq!(arena.emitted(slot), 0, "reused slot leaked emitted tokens");
                live.insert(slot, seed.id);
            } else {
                let keys: Vec<u32> = live.keys().copied().collect();
                let slot = *g.pick(&keys);
                live.remove(&slot);
                arena.release(slot);
                assert!(!arena.is_live(slot));
            }
            peak = peak.max(live.len());
            assert_eq!(arena.live_slots(), live.len());
        }
        // Capacity grows only when no retired slot is reusable, so it
        // lands exactly on the peak live count.
        assert_eq!(arena.capacity_slots(), peak);
        // Survivors are untouched by any interleaved reuse.
        for (&slot, &id) in &live {
            assert_eq!(arena.id_of(slot), id);
        }
    });
}

#[test]
fn prop_streaming_records_match_full_mode() {
    // Streaming-records mode gates only the per-request record pushes:
    // for any trace and any limits, a streaming drain must make the
    // identical scheduling decisions and land the identical scalar
    // counters and quantile sketches as the full-records drain.
    property(60, |g| {
        let n = g.usize_in(1, 30);
        let mut reqs = Vec::new();
        for i in 0..n {
            reqs.push(TraceRequest {
                id: i as u64,
                arrival_s: g.f64_in(0.0, 8.0),
                prompt_tokens: g.usize_in(1, 80),
                output_tokens: g.usize_in(1, 40),
            });
        }
        reqs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let limits = BatchLimits {
            max_batch_tokens: *g.pick(&[0usize, 64, 256]),
            kv_budget_bytes: if g.bool() { g.usize_in(50, 400) as f64 } else { f64::INFINITY },
            kv_bytes_per_token: 1.0,
            prefill_chunk_tokens: *g.pick(&[0usize, 16, 64]),
        };
        let mut full = Batcher::with_limits(limits);
        let mut lean = Batcher::with_limits(limits).with_streaming_records();
        full.enqueue(&reqs);
        lean.enqueue(&reqs);
        let mut clock = 0.0f64;
        let mut guard = 0u64;
        loop {
            assert_eq!(full.idle(), lean.idle(), "streaming mode changed idleness");
            if full.idle() {
                break;
            }
            let a = full.next_iteration(clock);
            let b = lean.next_iteration(clock);
            assert_eq!(a, b, "streaming mode changed scheduling at t={clock}");
            match a {
                Some(_) => {
                    full.complete_iteration(clock + 0.02);
                    lean.complete_iteration(clock + 0.02);
                }
                None => clock = full.next_arrival().unwrap_or(clock).max(clock),
            }
            clock += 0.05;
            guard += 1;
            assert!(guard < 500_000, "streaming differential must drain");
        }
        assert_eq!(full.admitted, lean.admitted);
        assert_eq!(full.completed, lean.completed);
        assert_eq!(full.rejected, lean.rejected);
        assert_eq!(full.preemptions, lean.preemptions);
        assert_eq!(full.resumes, lean.resumes);
        assert_eq!(full.tokens_prefilled, lean.tokens_prefilled);
        assert_eq!(full.tokens_decoded, lean.tokens_decoded);
        assert_eq!(full.tokens_recomputed, lean.tokens_recomputed);
        // Sketches are fed at the identical sites in both modes.
        assert!(full.ttft_sketch == lean.ttft_sketch, "ttft sketches diverged");
        assert!(full.e2e_sketch == lean.e2e_sketch, "e2e sketches diverged");
        assert_eq!(full.ttft_sketch.len(), full.ttft_ms.len());
        assert_eq!(full.e2e_sketch.len(), full.finished.len());
        // The records themselves are the one difference.
        assert!(lean.ttft_ms.is_empty() && lean.e2e_ms.is_empty() && lean.finished.is_empty());
    });
}

// ---------------------------------------------------------------------------
// Predictor invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_blend_extremes() {
    property(100, |g| {
        let n = g.usize_in(1, 16);
        let loads = g.loads(n, 500.0);
        let mut rng = Pcg::seeded(g.seed);
        // Perfect accuracy reproduces the input exactly (no noise at a=1).
        let perfect = blend_to_accuracy(&loads, 1.0, &mut rng);
        for (p, a) in perfect.iter().zip(&loads) {
            assert!((p - a).abs() < 1e-9);
        }
        // Any accuracy preserves non-negativity.
        let any = blend_to_accuracy(&loads, g.f64_in(0.0, 1.0), &mut rng);
        assert!(any.iter().all(|&x| x >= 0.0));
    });
}

#[test]
fn prop_accuracy_metrics_bounded() {
    property(200, |g| {
        let n = g.usize_in(1, 16);
        let a = g.loads(n, 100.0);
        let b = g.loads(n, 100.0);
        let k = g.usize_in(1, n);
        let o = topk_overlap(&a, &b, k);
        assert!((0.0..=1.0).contains(&o));
        assert_eq!(topk_overlap(&a, &a, k), 1.0);
        let e = l1_error(&a, &b);
        assert!((0.0..=1.0 + 1e-9).contains(&e));
        assert!(l1_error(&a, &a) < 1e-9);
    });
}

// ---------------------------------------------------------------------------
// Failure injection: memory-exhausted clusters must degrade, not crash.
// ---------------------------------------------------------------------------

#[test]
fn prop_tiny_cluster_never_panics() {
    property(40, |g| {
        use moeless::baselines::PolicyKind;
        use moeless::config::{DatasetSpec, ModelSpec};
        use moeless::sim::{run, SimConfig};
        let mut cfg = SimConfig::new(
            ModelSpec::mixtral_8x7b(),
            DatasetSpec::lmsys(),
            *g.pick(&[PolicyKind::Moeless, PolicyKind::MoelessAblated]),
        );
        // Pathologically small GPUs: evictions and placement fallbacks fire.
        cfg.cluster = ClusterSpec::a6000_x8()
            .with_n_gpus(g.usize_in(1, 2))
            .with_mem_per_gpu(g.f64_in(0.5, 2.0));
        cfg.duration_s = 4.0;
        cfg.base_rps = g.f64_in(0.5, 6.0);
        cfg.seed = g.seed;
        let r = run(&cfg);
        assert!(r.layer_forward.mean().is_finite() && r.layer_forward.max().is_finite());
    });
}

// ---------------------------------------------------------------------------
// Checkpoint-loading model (serverless::loading) laws.
// ---------------------------------------------------------------------------

#[test]
fn prop_cold_start_monotone_in_model_size_and_bandwidth() {
    use moeless::config::GpuSpec;
    use moeless::serverless::loading::{cold_start_s, Tier};
    property(200, |g| {
        let mut gpu = GpuSpec::a6000();
        gpu.nvme_gbps = g.f64_in(0.5, 50.0);
        gpu.dram_gbps = g.f64_in(1.0, 200.0);
        let gb_a = g.f64_in(0.1, 200.0);
        let gb_b = g.f64_in(0.1, 200.0);
        let (small, big) = if gb_a <= gb_b { (gb_a, gb_b) } else { (gb_b, gb_a) };
        for tier in [Tier::Hbm, Tier::Dram, Tier::Nvme] {
            // Monotone nondecreasing in checkpoint size.
            assert!(cold_start_s(small, tier, &gpu) <= cold_start_s(big, tier, &gpu));
            // Warm-resident is free; every colder tier costs at least as much.
            assert!(cold_start_s(small, Tier::Hbm, &gpu) == 0.0);
            assert!(cold_start_s(small, tier, &gpu) >= 0.0);
        }
        // Deeper tiers never beat shallower ones on the same hardware.
        assert!(cold_start_s(big, Tier::Dram, &gpu) <= cold_start_s(big, Tier::Nvme, &gpu));
        // Nonincreasing in each tier bandwidth, the other held fixed.
        let mut faster_nvme = gpu.clone();
        faster_nvme.nvme_gbps = gpu.nvme_gbps * g.f64_in(1.0, 8.0);
        assert!(cold_start_s(big, Tier::Nvme, &faster_nvme) <= cold_start_s(big, Tier::Nvme, &gpu));
        let mut faster_dram = gpu.clone();
        faster_dram.dram_gbps = gpu.dram_gbps * g.f64_in(1.0, 8.0);
        assert!(cold_start_s(big, Tier::Dram, &faster_dram) <= cold_start_s(big, Tier::Dram, &gpu));
        assert!(cold_start_s(big, Tier::Nvme, &faster_dram) <= cold_start_s(big, Tier::Nvme, &gpu));
    });
}

#[test]
fn prop_warm_ledger_never_oversubscribes_any_device() {
    use moeless::serverless::loading::WarmStore;
    property(150, |g| {
        let n_gpus = g.usize_in(1, 4);
        let hbm_gb = g.f64_in(4.0, 32.0);
        let dram_gb = g.f64_in(0.0, 48.0);
        let mut spec = ClusterSpec::a6000_x8().with_n_gpus(n_gpus).with_mem_per_gpu(hbm_gb);
        spec.dram_cache_gb = dram_gb;
        let mut store = WarmStore::new(&spec);
        let n_models = g.usize_in(1, 12);
        let sizes: Vec<f64> = (0..n_models).map(|_| g.f64_in(0.5, 20.0)).collect();
        let mut pins = vec![vec![0u32; n_models]; n_gpus];
        for _ in 0..g.usize_in(1, 120) {
            let gpu = g.usize_in(0, n_gpus - 1);
            let m = g.usize_in(0, n_models - 1) as u32;
            match g.usize_in(0, 5) {
                0 | 1 => {
                    // Admission either fits (possibly after LRU eviction of
                    // unpinned residents) or refuses outright.
                    store.admit(gpu, m, sizes[m as usize]);
                }
                2 => {
                    store.evict(gpu, m);
                }
                3 => {
                    // Only pin what a real arrival pins: an admitted model.
                    if store.is_warm(gpu, m) {
                        store.pin(gpu, m);
                        pins[gpu][m as usize] += 1;
                    }
                }
                4 => {
                    if pins[gpu][m as usize] > 0 {
                        store.unpin(gpu, m);
                        pins[gpu][m as usize] -= 1;
                    }
                }
                _ => {
                    store.stage_dram(m, sizes[m as usize]);
                    store.touch(gpu, m);
                }
            }
            // The invariant: no device ledger ever exceeds its capacity,
            // regardless of the admit/evict/pin/touch interleaving.
            for dev in 0..n_gpus {
                assert!(
                    store.used_gb(dev) <= store.capacity_gb(dev) + 1e-9,
                    "device {dev}: {} GB used of {} GB",
                    store.used_gb(dev),
                    store.capacity_gb(dev)
                );
            }
            assert!(store.dram_used_gb() <= dram_gb + 1e-9);
        }
    });
}

// ---------------------------------------------------------------------------
// Expert-offloading residency hierarchy (PR 10) invariants.
// ---------------------------------------------------------------------------

#[test]
fn prop_expert_store_never_oversubscribes_any_tier() {
    use moeless::config::{ModelSpec, MoelessParams};
    use moeless::serverless::offload::ExpertStore;
    property(120, |g| {
        let n_gpus = g.usize_in(1, 4);
        let mut spec = ClusterSpec::a6000_x8().with_n_gpus(n_gpus);
        spec.dram_cache_gb = g.f64_in(0.0, 8.0);
        let model = ModelSpec::mixtral_8x7b();
        let params = MoelessParams {
            expert_hbm_frac: g.f64_in(0.01, 0.9),
            prefetch_lookahead: g.usize_in(0, 4),
            demand_fetch: g.usize_in(0, 1) == 1,
            ..Default::default()
        };
        let mut store = ExpertStore::new(&model, &spec, &params);
        let mut vnow = 0.0f64;
        for _ in 0..g.usize_in(1, 60) {
            vnow += g.f64_in(0.0, 0.5);
            let layer = g.usize_in(0, model.n_layers - 1);
            let mut pairs: Vec<(usize, usize)> = Vec::new();
            let mut covered = Vec::new();
            for _ in 0..g.usize_in(1, 6) {
                let pr = (g.usize_in(0, model.n_experts - 1), g.usize_in(0, n_gpus - 1));
                if !pairs.contains(&pr) {
                    pairs.push(pr);
                    covered.push(g.usize_in(0, 1) == 1);
                }
            }
            let issue = vnow - g.f64_in(0.0, 1.0);
            store.serve(layer, &pairs, &covered, issue, vnow);
            // The invariant: no tier's ledger ever exceeds its capacity,
            // whatever the layer/pair/coverage interleaving.
            for dev in 0..store.n_devices() {
                assert!(
                    store.hbm_used_gb(dev) <= store.hbm_capacity_gb(dev) + 1e-9,
                    "device {dev}: {} GB used of {} GB",
                    store.hbm_used_gb(dev),
                    store.hbm_capacity_gb(dev)
                );
            }
            assert!(store.dram_used_gb() <= spec.dram_cache_gb + 1e-9);
        }
    });
}

#[test]
fn prop_covered_prefetch_with_slack_never_stalls() {
    // The Oracle-with-headroom property at the store level: when every
    // pair is predictor-covered and the issue instant leads the layer by
    // more than the whole run's worst-case serialized transfer time, no
    // fetch can land on the critical path — the stall is exactly 0.0.
    use moeless::config::{ModelSpec, MoelessParams};
    use moeless::serverless::offload::ExpertStore;
    property(100, |g| {
        let n_gpus = g.usize_in(1, 4);
        let spec = ClusterSpec::a6000_x8().with_n_gpus(n_gpus);
        let model = ModelSpec::mixtral_8x7b();
        let params = MoelessParams {
            expert_hbm_frac: g.f64_in(0.05, 0.9),
            prefetch_lookahead: 2,
            demand_fetch: false,
            ..Default::default()
        };
        let mut store = ExpertStore::new(&model, &spec, &params);
        let worst_transfer = spec
            .gpus
            .iter()
            .map(|gp| model.expert_mem_gb / gp.nvme_gbps + model.expert_mem_gb / gp.dram_gbps)
            .fold(0.0, f64::max);
        let steps = g.usize_in(1, 40);
        let slack = worst_transfer * (steps * 6) as f64 + 1.0;
        let mut vnow = slack;
        let mut total_stall_ms = 0.0;
        for _ in 0..steps {
            vnow += g.f64_in(0.01, 0.5);
            let layer = g.usize_in(0, model.n_layers - 1);
            let mut pairs: Vec<(usize, usize)> = Vec::new();
            for _ in 0..g.usize_in(1, 6) {
                let pr = (g.usize_in(0, model.n_experts - 1), g.usize_in(0, n_gpus - 1));
                if !pairs.contains(&pr) {
                    pairs.push(pr);
                }
            }
            let covered = vec![true; pairs.len()];
            total_stall_ms += store.serve(layer, &pairs, &covered, vnow - slack, vnow);
        }
        assert_eq!(total_stall_ms, 0.0, "slack-covered prefetch must never stall");
        assert_eq!(store.stats.prefetch_misses, 0);
    });
}

#[test]
fn prop_stall_monotone_nonincreasing_in_fetch_bandwidth() {
    // Residency and eviction decisions depend only on the fetch call
    // sequence, never on the clock — so speeding up DRAM/NVMe transfers
    // on the identical scripted serve sequence can only shrink stalls.
    use moeless::config::{ModelSpec, MoelessParams};
    use moeless::serverless::offload::ExpertStore;
    property(100, |g| {
        let n_gpus = g.usize_in(1, 4);
        let mut slow = ClusterSpec::a6000_x8().with_n_gpus(n_gpus);
        let mut fast = slow.clone();
        for (s, f) in slow.gpus.iter_mut().zip(fast.gpus.iter_mut()) {
            s.nvme_gbps = g.f64_in(0.5, 10.0);
            s.dram_gbps = g.f64_in(1.0, 50.0);
            f.nvme_gbps = s.nvme_gbps * g.f64_in(1.0, 8.0);
            f.dram_gbps = s.dram_gbps * g.f64_in(1.0, 8.0);
        }
        let model = ModelSpec::mixtral_8x7b();
        let params = MoelessParams {
            expert_hbm_frac: g.f64_in(0.05, 0.9),
            prefetch_lookahead: 2,
            demand_fetch: false,
            ..Default::default()
        };
        // Script the whole sequence first so both stores replay the
        // identical calls (the generator is consulted only once).
        let steps = g.usize_in(1, 40);
        let mut script = Vec::with_capacity(steps);
        let mut vnow = 0.0f64;
        for _ in 0..steps {
            vnow += g.f64_in(0.01, 0.5);
            let layer = g.usize_in(0, model.n_layers - 1);
            let mut pairs: Vec<(usize, usize)> = Vec::new();
            let mut covered = Vec::new();
            for _ in 0..g.usize_in(1, 6) {
                let pr = (g.usize_in(0, model.n_experts - 1), g.usize_in(0, n_gpus - 1));
                if !pairs.contains(&pr) {
                    pairs.push(pr);
                    covered.push(g.usize_in(0, 1) == 1);
                }
            }
            let issue = vnow - g.f64_in(0.0, 2.0);
            script.push((layer, pairs, covered, issue, vnow));
        }
        let mut replay = |spec: &ClusterSpec| -> f64 {
            let mut store = ExpertStore::new(&model, spec, &params);
            let mut total = 0.0;
            for (layer, pairs, covered, issue, at) in &script {
                total += store.serve(*layer, pairs, covered, *issue, *at);
            }
            total
        };
        let slow_stall = replay(&slow);
        let fast_stall = replay(&fast);
        assert!(
            fast_stall <= slow_stall + 1e-9,
            "faster tiers must not stall more: fast {fast_stall:.3}ms vs slow {slow_stall:.3}ms"
        );
    });
}
