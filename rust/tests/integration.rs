//! Cross-module integration tests (Tier B): policies × workloads × models
//! composed through the full simulation driver, checking the paper's
//! qualitative claims end to end.

use moeless::baselines::PolicyKind;
use moeless::config::{DatasetSpec, DisaggSpec, ModelSpec, MoelessParams};
use moeless::metrics::{reduction_pct, SloSpec};
use moeless::sim::{run, SimConfig};
use moeless::workload::{burst_trace, interference_trace, Scenario};

fn cfg(model: ModelSpec, policy: PolicyKind) -> SimConfig {
    let mut c = SimConfig::new(model, DatasetSpec::lmsys(), policy);
    c.duration_s = 45.0;
    c.base_rps = 8.0;
    c.seed = 77;
    c
}

#[test]
fn all_policies_all_models_complete() {
    for model in ModelSpec::paper_models() {
        for kind in PolicyKind::paper_set() {
            let mut c = cfg(model.clone(), kind);
            c.duration_s = 15.0;
            let r = run(&c);
            assert!(r.iterations > 5, "{} {}: {} iters", model.name, kind.name(), r.iterations);
            assert!(r.completed_requests > 0, "{} {}", model.name, kind.name());
            assert!(r.layer_forward.min() > 0.0 && r.layer_forward.max().is_finite());
            assert!(r.cost_gb_s > 0.0);
        }
    }
}

#[test]
fn headline_latency_ordering_mixtral() {
    let meg = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Megatron));
    let eplb = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Eplb));
    let orc = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Oracle));
    let less = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));

    // Paper §6.2: MoEless < EPLB < Megatron-LM; MoEless closest to Oracle.
    assert!(less.mean_layer_ms() < eplb.mean_layer_ms());
    assert!(eplb.mean_layer_ms() < meg.mean_layer_ms());
    let vs_meg = reduction_pct(meg.mean_layer_ms(), less.mean_layer_ms());
    assert!(
        (25.0..70.0).contains(&vs_meg),
        "latency reduction vs megatron should be in the paper's ballpark (43%), got {vs_meg:.1}%"
    );
    // Closest to oracle: within 15% of its mean.
    assert!(less.mean_layer_ms() < orc.mean_layer_ms() * 1.15);
}

#[test]
fn headline_cost_reduction() {
    let meg = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Megatron));
    let eplb = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Eplb));
    let less = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));
    // Paper: -92.7% vs Megatron-LM, -95.1% vs EPLB (EPLB costs the most).
    assert!(eplb.cost_gb_s > meg.cost_gb_s, "EPLB's redundant slots cost extra");
    let vs_meg = reduction_pct(meg.cost_gb_s, less.cost_gb_s);
    assert!(vs_meg > 80.0, "cost reduction vs megatron, got {vs_meg:.1}%");
}

#[test]
fn tail_latency_also_improves() {
    let meg = run(&cfg(ModelSpec::phi_3_5_moe(), PolicyKind::Megatron));
    let less = run(&cfg(ModelSpec::phi_3_5_moe(), PolicyKind::Moeless));
    assert!(less.layer_latency().p(99.0) < meg.layer_latency().p(99.0));
}

#[test]
fn distance_sensitivity_tradeoff() {
    // Fig. 13: latency rises with d while replicas fall.
    let mut lat = Vec::new();
    let mut rep = Vec::new();
    for d in [1usize, 5] {
        let mut c = cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless);
        c.params = MoelessParams { prediction_distance: d, ..Default::default() };
        let r = run(&c);
        lat.push(r.mean_layer_ms());
        rep.push(r.mean_replicas());
    }
    assert!(lat[1] > lat[0] * 0.99, "latency d=5 {} vs d=1 {}", lat[1], lat[0]);
    assert!(rep[1] < rep[0], "replicas d=5 {} vs d=1 {}", rep[1], rep[0]);
}

#[test]
fn cv_sensitivity_tradeoff() {
    // Fig. 15: looser V => fewer replicas, higher latency.
    let mut out = Vec::new();
    for v in [0.2, 1.0] {
        let mut c = cfg(ModelSpec::phi_3_5_moe(), PolicyKind::Moeless);
        c.params = MoelessParams { cv_threshold: v, ..Default::default() };
        let r = run(&c);
        out.push((r.mean_layer_ms(), r.mean_replicas()));
    }
    assert!(out[1].1 < out[0].1, "replicas: {:?}", out);
    assert!(out[1].0 > out[0].0 * 0.98, "latency: {:?}", out);
}

#[test]
fn ablation_degrades_moeless() {
    let full = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));
    let ablated = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::MoelessAblated));
    assert!(full.mean_layer_ms() < ablated.mean_layer_ms());
}

#[test]
fn serverless_diagnostics_healthy() {
    // §6.6: nearly all operations warm-started. Mixtral (top-2, 8 experts)
    // keeps every expert hot; Llama-4-Scout (top-1, 16 experts, 48 layers)
    // has flickering cold experts and sits a little lower.
    let mix = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));
    assert!(mix.warm_fraction > 0.95, "warm fraction {}", mix.warm_fraction);
    let llama = run(&cfg(ModelSpec::llama_4_scout(), PolicyKind::Moeless));
    assert!(llama.warm_fraction > 0.75, "warm fraction {}", llama.warm_fraction);
    assert!(llama.residency_gb_s > 0.0);
    assert!(llama.mean_pred_accuracy() > 0.8);
}

#[test]
fn reports_are_deterministic_across_policies() {
    for kind in [PolicyKind::Moeless, PolicyKind::Eplb] {
        let a = run(&cfg(ModelSpec::mixtral_8x7b(), kind));
        let b = run(&cfg(ModelSpec::mixtral_8x7b(), kind));
        assert_eq!(a.layer_forward, b.layer_forward, "{}", kind.name());
        assert_eq!(a.cost_gb_s, b.cost_gb_s);
    }
}

#[test]
fn higher_load_amplifies_moeless_advantage() {
    // The straggler term grows with batch size; so must MoEless's edge.
    let gain = |rps: f64| {
        let mut m = cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Megatron);
        m.base_rps = rps;
        let mut l = cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless);
        l.base_rps = rps;
        reduction_pct(run(&m).mean_layer_ms(), run(&l).mean_layer_ms())
    };
    let low = gain(1.0);
    let high = gain(10.0);
    assert!(high > low, "low-load {low:.1}% vs high-load {high:.1}%");
}

#[test]
fn slo_metrics_reported() {
    let r = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));
    assert_eq!(r.e2e_ms.len() as u64, r.completed_requests);
    assert!(r.ttft_ms.len() as u64 >= r.completed_requests);
    // TTFT <= e2e for every request distribution-wise.
    assert!(r.ttft_cdf().p(50.0) <= r.e2e_cdf().p(50.0));
    assert!(r.ttft_cdf().p(99.0) > 0.0);
    // MoEless's lower iteration latency shows up in TTFT too.
    let meg = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Megatron));
    assert!(r.ttft_cdf().p(99.0) <= meg.ttft_cdf().p(99.0) * 1.1);
}

#[test]
fn kv_oversubscription_preempts_without_losing_requests() {
    // Deterministic oversubscription: 24 simultaneous requests whose
    // aggregate prompt KV (24 × 400 = 9600 tokens) far exceeds a
    // 0.004 GB ≈ 3906-token budget (TinyMoE holds 1 KiB of KV per
    // token), while each single request's peak (400 + 120 = 520 tokens)
    // fits comfortably. Admission must queue behind headroom, decode
    // growth must preempt, and every request must still drain.
    let mk = |budget_gb: Option<f64>| {
        let mut c =
            SimConfig::new(ModelSpec::tiny_moe(), DatasetSpec::lmsys(), PolicyKind::Moeless);
        c.scenario = Scenario::replay(burst_trace(24, 0.0, 400, 120));
        c.duration_s = 60.0;
        c.seed = 7;
        c.kv_budget_override_gb = budget_gb;
        c
    };
    let constrained = run(&mk(Some(0.004)));
    let baseline = run(&mk(None)); // derived budget: no pressure at this scale

    // The budget binds: preemption + delay churn, near-full utilization.
    assert!(constrained.preemptions > 0, "oversubscription must preempt");
    assert!(constrained.delayed_admissions > 0);
    assert!(constrained.tokens_recomputed > 0, "resume recomputes context");
    assert!(constrained.peak_kv_util() > 0.8, "{}", constrained.peak_kv_util());
    assert!(constrained.peak_kv_util() <= 1.0 + 1e-9, "occupancy stays within budget");

    // No request is lost, and accounting balances at drain:
    // admitted = completed, every preemption was resumed, and the
    // per-request preemption counts add up to the run total.
    assert_eq!(constrained.rejected_requests, 0, "every peak fits: nothing rejected");
    assert_eq!(constrained.completed_requests, 24);
    assert_eq!(constrained.requests.len(), 24);
    assert_eq!(constrained.resumes, constrained.preemptions);
    let per_request: u64 = constrained.requests.iter().map(|r| r.preemptions as u64).sum();
    assert_eq!(per_request, constrained.preemptions);

    // Same seed without pressure: zero churn, lower tail TTFT, shorter
    // serving time — the acceptance A/B.
    assert_eq!((baseline.preemptions, baseline.rejected_requests), (0, 0));
    assert_eq!(baseline.completed_requests, 24);
    assert!(
        constrained.ttft_cdf().p(99.0) > baseline.ttft_cdf().p(99.0),
        "pressure must inflate tail TTFT: {} vs {}",
        constrained.ttft_cdf().p(99.0),
        baseline.ttft_cdf().p(99.0)
    );
    assert!(constrained.sim_duration_s > baseline.sim_duration_s);

    // The oversubscribed run is bit-for-bit reproducible.
    let again = run(&mk(Some(0.004)));
    assert_eq!(constrained.requests, again.requests);
    assert_eq!(constrained.preemptions, again.preemptions);
}

#[test]
fn kv_budget_pressure_degrades_goodput_monotonically() {
    // With the KV carve-out halved (and then slashed), goodput under the
    // default SLO degrades monotonically-or-equal for every policy, and
    // MoEless still beats Megatron-LM on p99 TTFT under the halved
    // budget.
    let slo = SloSpec::default();
    let at = |kind: PolicyKind, frac: f64| {
        let mut c = cfg(ModelSpec::mixtral_8x7b(), kind);
        c.duration_s = 25.0;
        c.base_rps = 10.0;
        c.kv_frac = frac;
        run(&c)
    };
    for kind in PolicyKind::paper_set() {
        let full = at(kind, 1.0);
        let half = at(kind, 0.5);
        let tight = at(kind, 0.02);
        let (gf, gh, gt) =
            (full.goodput_rps(&slo), half.goodput_rps(&slo), tight.goodput_rps(&slo));
        assert!(gh <= gf + 1e-9, "{}: half {gh} > full {gf}", kind.name());
        assert!(gt <= gh + 1e-9, "{}: tight {gt} > half {gh}", kind.name());
        assert!(
            tight.preemptions + tight.delayed_admissions > 0,
            "{}: a 2% carve-out must bind at this load",
            kind.name()
        );
    }
    let meg = at(PolicyKind::Megatron, 0.5);
    let less = at(PolicyKind::Moeless, 0.5);
    assert!(
        less.ttft_cdf().p(99.0) < meg.ttft_cdf().p(99.0),
        "moeless p99 ttft {} vs megatron {}",
        less.ttft_cdf().p(99.0),
        meg.ttft_cdf().p(99.0)
    );
}

#[test]
fn chunked_prefill_beats_monolithic_p99_tpot_on_interference_mix() {
    // The interference regression the chunked-prefill work is locked in
    // by: a steady decode stream (20 small req/s, 6-token outputs, so a
    // stall dominates the few inter-token gaps it lands in) with a
    // 4096-token prompt landing every 5 s. Under monolithic prefill each
    // long prompt stalls every co-scheduled decode for its whole length —
    // the inter-token gap (TPOT tail) spikes. With a 512-token chunk
    // budget the stall is bounded per iteration, so chunked p99 TPOT must
    // beat monolithic at equal goodput. Megatron-LM's static EP isolates
    // the phase interference from serverless scaling (no cold-start or
    // replica-count jitter between the two runs); the trace is
    // deterministic and the duration outlasts the arrivals, so both
    // configurations drain every request.
    let mix = interference_trace(30.0, 20.0, 32, 6, 5.0, 4096, 8);
    let n_requests = mix.len() as u64;
    let mk = |chunk: usize| {
        let mut c =
            SimConfig::new(ModelSpec::mixtral_8x7b(), DatasetSpec::lmsys(), PolicyKind::Megatron);
        c.scenario = Scenario::replay(mix.clone());
        c.duration_s = 300.0;
        c.seed = 7;
        c.prefill_chunk_tokens = chunk;
        c
    };
    let mono = run(&mk(0));
    let chunked = run(&mk(512));

    // Equal goodput base: both drain the identical request set, and the
    // same number of requests meet the SLO (counted, not divided by the
    // runs' slightly different drain tails).
    assert_eq!(mono.completed_requests, n_requests);
    assert_eq!(chunked.completed_requests, n_requests);
    let slo = SloSpec::default();
    let good = |r: &moeless::metrics::RunReport| {
        r.requests.iter().filter(|q| slo.met(q)).count()
    };
    assert!(
        good(&chunked) >= good(&mono),
        "chunking must not cost goodput: {} vs {} SLO-good requests",
        good(&chunked),
        good(&mono)
    );
    // ...and the acceptance headline: the decode tail un-stalls.
    assert!(
        chunked.tpot_p99_ms() < mono.tpot_p99_ms(),
        "chunked p99 TPOT {} must beat monolithic {}",
        chunked.tpot_p99_ms(),
        mono.tpot_p99_ms()
    );
    // The long prompts were actually split (decode packs first, so each
    // chunk is below the 512-token budget: >=8 chunks for 4096 tokens),
    // and TTFT was recorded once per request, on last-chunk completion.
    let long_chunks = chunked
        .requests
        .iter()
        .filter(|r| r.prompt_tokens == 4096)
        .map(|r| r.chunks)
        .collect::<Vec<_>>();
    assert_eq!(long_chunks.len(), 6);
    assert!(long_chunks.iter().all(|&c| c >= 8), "{long_chunks:?}");
    assert_eq!(chunked.ttft_ms.len() as u64, n_requests);
    assert!((mono.mean_chunks_per_request() - 1.0).abs() < 1e-12);
    // Deterministic: the regression is stable, not a coin flip.
    let again = run(&mk(512));
    assert_eq!(chunked.requests, again.requests);
}

#[test]
fn disaggregated_kv_transfer_matches_golden_accounting() {
    // Fixed-seed golden test for the disaggregated KV-transfer ledger:
    // 8 simultaneous 400-token prompts on TinyMoE (1 KiB of KV per token,
    // 2·4 layers·64 d_model·2 B) each ship exactly 400 KiB of cache at
    // their prefill→decode handoff: 8 × 400 × 1024 B = 3.2768e-3 GB.
    // The derived KV budget dwarfs the demand, so no preemption ever
    // re-ships a cache, chunked or not.
    let mk = |chunk: usize| {
        let mut c =
            SimConfig::new(ModelSpec::tiny_moe(), DatasetSpec::lmsys(), PolicyKind::Moeless);
        c.scenario = Scenario::replay(burst_trace(8, 0.0, 400, 30));
        c.duration_s = 120.0;
        c.seed = 13;
        c.prefill_chunk_tokens = chunk;
        // A deliberately slow 0.01 GB/s link: each 400 KiB handoff costs
        // ~41 ms, far above pool-to-pool policy noise, so the TTFT
        // comparison against the colocated run is deterministic.
        c.disagg = Some(DisaggSpec {
            link_gbps: 0.01,
            ..DisaggSpec::even_split(&c.cluster)
        });
        c
    };
    let golden_gb = 8.0 * 400.0 * 1024.0 / 1e9;
    let mono = run(&mk(0));
    assert_eq!(mono.completed_requests, 8);
    assert_eq!((mono.preemptions, mono.rejected_requests), (0, 0));
    assert!(
        (mono.kv_transfer_gb - golden_gb).abs() < 1e-12,
        "golden kv_transfer: {} vs {golden_gb}",
        mono.kv_transfer_gb
    );
    // Chunking reshapes iterations but the handoff volume is invariant:
    // one transfer per request, of exactly its prompt's KV.
    let chunked = run(&mk(128));
    assert_eq!(chunked.completed_requests, 8);
    assert!((chunked.kv_transfer_gb - golden_gb).abs() < 1e-12);
    assert!(chunked.mean_chunks_per_request() > 1.0);
    // Both pools actually worked, and the handoff delayed first tokens
    // relative to a colocated run of the same trace.
    assert!(mono.prefill_pool_util > 0.0 && mono.decode_pool_util > 0.0);
    let mut colocated = mk(0);
    colocated.disagg = None;
    let colo = run(&colocated);
    assert_eq!(colo.kv_transfer_gb, 0.0, "colocated runs ship nothing");
    assert!(
        mono.ttft_cdf().p(50.0) > colo.ttft_cdf().p(50.0) + 30.0,
        "each first token must pay the ~41ms handoff: {} vs {}",
        mono.ttft_cdf().p(50.0),
        colo.ttft_cdf().p(50.0)
    );
    // Bit-for-bit reproducible.
    let again = run(&mk(0));
    assert_eq!(mono.requests, again.requests);
    assert_eq!(mono.kv_transfer_gb, again.kv_transfer_gb);
}

#[test]
fn hetero_capacity_aware_beats_token_balanced_on_p99_latency() {
    // The heterogeneous-fleet acceptance regression: the same bursty
    // trace on the same mixed 2×H100 + 6×A6000 fleet, with and without
    // capacity-aware decisions (the cost model always evaluates on the
    // real per-device speeds). Routing skew concentrates load on hot
    // experts whose replicas the time-greedy placer stacks on the H100s,
    // so both the mean and the p99 layer forward must improve, and the
    // request tail must not regress.
    use moeless::config::ClusterSpec;
    let mk = |aware: bool| {
        let mut c = cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless);
        c.scenario = Scenario::bursty();
        c.duration_s = 30.0;
        c.cluster = ClusterSpec::hetero_h100_a6000();
        c.cluster.capacity_aware = aware;
        c
    };
    let aware = run(&mk(true));
    let balanced = run(&mk(false));
    assert!(
        aware.layer_forward.p(99.0) < balanced.layer_forward.p(99.0),
        "p99 layer forward: aware {} vs token-balanced {}",
        aware.layer_forward.p(99.0),
        balanced.layer_forward.p(99.0)
    );
    assert!(aware.mean_layer_ms() < balanced.mean_layer_ms());
    assert!(aware.ttft_cdf().p(99.0) <= balanced.ttft_cdf().p(99.0) * 1.05);
    // Per-GPU utilization signals are populated and skewed the right way:
    // capacity-aware serving pushes tokens toward the H100s.
    assert_eq!(aware.gpu_tokens.len(), 8);
    let h100_share = |r: &moeless::metrics::RunReport| {
        let total: f64 = r.gpu_tokens.iter().sum();
        r.gpu_tokens[..2].iter().sum::<f64>() / total.max(1e-12)
    };
    assert!(h100_share(&aware) > h100_share(&balanced));
    assert!(aware.gpu_line().contains("util="), "{}", aware.gpu_line());
    // Determinism: the regression is stable, not a coin flip.
    let again = run(&mk(true));
    assert_eq!(aware.requests, again.requests);
    assert_eq!(aware.gpu_busy_ms, again.gpu_busy_ms);
}

#[test]
fn hetero_disagg_fastest_prefill_smoke() {
    // Mixed fleet + disaggregation with the fastest devices steered to
    // prefill: the run completes, ships KV, reports per-pool and per-GPU
    // signals, and is deterministic.
    use moeless::config::ClusterSpec;
    let mut c = cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless);
    c.duration_s = 20.0;
    c.cluster = ClusterSpec::hetero_h100_a6000();
    c.prefill_chunk_tokens = 256;
    c.disagg = Some(DisaggSpec { prefill_gpus: 2, decode_gpus: 6, ..DisaggSpec::fastest_split(&c.cluster) });
    let r = run(&c);
    assert!(r.completed_requests > 0);
    assert!(r.kv_transfer_gb > 0.0);
    assert!(r.prefill_pool_util > 0.0 && r.decode_pool_util > 0.0);
    assert_eq!(r.gpu_tokens.len(), 8);
    // The prefill pool is exactly the two H100s (indices 0, 1): they see
    // prompt tokens, and the decode pool's A6000s see decode work.
    assert!(r.gpu_tokens[..2].iter().sum::<f64>() > 0.0);
    assert!(r.gpu_tokens[2..].iter().sum::<f64>() > 0.0);
    assert!(r.dollar_cost > 0.0);
    let again = run(&c);
    assert_eq!(r.requests, again.requests);
    assert_eq!(r.gpu_tokens, again.gpu_tokens);
}

#[test]
fn serverful_bills_more_dollars_than_serverless_on_the_same_fleet() {
    // The Fig. 10 cost gap, in per-device dollars: a serverful baseline
    // reserves the whole fleet for every busy second; MoEless pays for
    // the device fractions its instances actually occupy.
    let less = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));
    let meg = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Megatron));
    assert!(less.dollar_cost > 0.0);
    assert!(meg.dollar_cost > less.dollar_cost, "{} vs {}", meg.dollar_cost, less.dollar_cost);
}

#[test]
fn autotune_trades_replicas_for_bounded_latency() {
    // The future-work extension: with the auto-tuner on, T_misc-dominated
    // workloads shed replica cost without catastrophic latency loss.
    let base = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless));
    let mut c = cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Moeless);
    c.autotune = true;
    let tuned = run(&c);
    assert!(tuned.mean_replicas() <= base.mean_replicas() + 0.5);
    assert!(tuned.mean_layer_ms() < base.mean_layer_ms() * 1.5);
    // And it still beats the serverful baseline.
    let meg = run(&cfg(ModelSpec::mixtral_8x7b(), PolicyKind::Megatron));
    assert!(tuned.mean_layer_ms() < meg.mean_layer_ms());
}

#[test]
fn autotune_is_deterministic() {
    let mut a = cfg(ModelSpec::phi_3_5_moe(), PolicyKind::Moeless);
    a.autotune = true;
    let mut b = cfg(ModelSpec::phi_3_5_moe(), PolicyKind::Moeless);
    b.autotune = true;
    assert_eq!(run(&a).layer_forward, run(&b).layer_forward);
}
