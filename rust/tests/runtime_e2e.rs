//! Tier-A end-to-end tests over real PJRT artifacts: the decomposed
//! serverless serving path must reproduce the monolithic compiled model,
//! under every knob setting, and the serving loop must behave like a
//! serving loop. Skipped gracefully when `make artifacts` hasn't run.

use moeless::config::MoelessParams;
use moeless::model::{length_mask, monolithic_logits, open_default, DecomposedServer, ModelDims};
use moeless::util::rng::Pcg;

fn artifacts_present() -> bool {
    moeless::tensor::store::artifacts_dir().join("manifest.json").exists()
}

fn batch(dims: ModelDims, seed: u64) -> (Vec<i32>, Vec<usize>) {
    let mut rng = Pcg::seeded(seed);
    let tokens = (0..dims.n_tokens()).map(|_| rng.below(dims.vocab) as i32).collect();
    let lens = (0..dims.batch).map(|_| rng.range(dims.seq / 2, dims.seq + 1)).collect();
    (tokens, lens)
}

#[test]
fn decomposed_equals_monolithic_multiple_batches() {
    if !artifacts_present() {
        return;
    }
    let mut srv = DecomposedServer::open_default(MoelessParams::default()).unwrap();
    let (mut store, rt) = open_default().unwrap();
    let dims = srv.dims;
    for seed in [1u64, 2, 3] {
        let (tokens, lens) = batch(dims, seed);
        let (deco, _) = srv.forward(&tokens, &lens).unwrap();
        let mono =
            monolithic_logits(&rt, &mut store, &tokens, &length_mask(&lens, dims.batch, dims.seq))
                .unwrap();
        let diff = deco.max_abs_diff(&mono);
        assert!(diff < 1e-3, "seed {seed}: max |Δ| = {diff}");
    }
}

#[test]
fn equivalence_holds_across_knobs() {
    if !artifacts_present() {
        return;
    }
    // Routing correctness must be invariant to every coordinator knob:
    // prediction distance, CV threshold, predictor on/off.
    let dims = DecomposedServer::open_default(MoelessParams::default()).unwrap().dims;
    let (tokens, lens) = batch(dims, 9);
    let mut reference: Option<moeless::tensor::Tensor> = None;
    for (d, v, use_pred) in [(1usize, 0.2f64, true), (2, 0.2, true), (3, 1.0, true), (1, 0.6, false)] {
        let params = MoelessParams {
            prediction_distance: d,
            cv_threshold: v,
            ..Default::default()
        };
        let mut srv = DecomposedServer::open_default(params).unwrap();
        srv.use_predictor = use_pred;
        let (logits, _) = srv.forward(&tokens, &lens).unwrap();
        match &reference {
            None => reference = Some(logits),
            Some(r) => {
                let diff = logits.max_abs_diff(r);
                assert!(diff < 1e-4, "d={d} V={v} pred={use_pred}: {diff}");
            }
        }
    }
}

#[test]
fn capacity_overflow_spawns_replicas() {
    if !artifacts_present() {
        return;
    }
    // With CV threshold 0 the scaler is maximally aggressive; with a
    // degenerate token stream all tokens route the same way, overflowing
    // one expert's capacity tile and forcing multi-instance fan-out.
    let params = MoelessParams { cv_threshold: 0.0, ..Default::default() };
    let mut srv = DecomposedServer::open_default(params).unwrap();
    let dims = srv.dims;
    let tokens = vec![5i32; dims.n_tokens()]; // identical tokens everywhere
    let lens = vec![dims.seq; dims.batch];
    let (logits, stats) = srv.forward(&tokens, &lens).unwrap();
    assert!(logits.data.iter().all(|x| x.is_finite()));
    // n_tokens=128 identical tokens x top-2 > capacity 64 per expert:
    // at least one expert needed two instances.
    assert!(
        stats.expert_invocations > dims.top_k * dims.n_layers,
        "{} invocations",
        stats.expert_invocations
    );
}

#[test]
fn generation_is_deterministic_and_causal() {
    if !artifacts_present() {
        return;
    }
    let mut a = DecomposedServer::open_default(MoelessParams::default()).unwrap();
    let mut b = DecomposedServer::open_default(MoelessParams::default()).unwrap();
    let dims = a.dims;
    let prompts: Vec<Vec<i32>> = (0..dims.batch)
        .map(|i| (0..4 + i).map(|j| ((j * 13 + i) % dims.vocab) as i32).collect())
        .collect();
    let (s1, _) = a.generate(&prompts, 4).unwrap();
    let (s2, _) = b.generate(&prompts, 4).unwrap();
    assert_eq!(s1, s2, "greedy decode must be deterministic");
    // Prompts are preserved as prefixes (causality).
    for (p, s) in prompts.iter().zip(&s1) {
        assert_eq!(&s[..p.len()], &p[..]);
    }
}

#[test]
fn serving_stats_accumulate_sanely() {
    if !artifacts_present() {
        return;
    }
    let mut srv = DecomposedServer::open_default(MoelessParams::default()).unwrap();
    let dims = srv.dims;
    let (tokens, lens) = batch(dims, 21);
    let (_, s1) = srv.forward(&tokens, &lens).unwrap();
    let (_, s2) = srv.forward(&tokens, &lens).unwrap();
    // Second pass over the same batch reuses warm instances.
    assert!(s2.warm_starts >= s1.warm_starts);
    assert!(s2.cold_starts <= s1.cold_starts);
    assert!(s1.expert_invocations >= dims.n_layers, "at least one expert call per layer");
}
