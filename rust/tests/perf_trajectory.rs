//! Perf-trajectory guard + recorder.
//!
//! Measures the frozen pre-PR-4 reference core against the optimized
//! core (same machine, same process) at the quick and saturated scales,
//! asserts the optimized core wins on the saturated drain, and records
//! the numbers to `BENCH_sim.json` at the repository root — so every
//! tier-1 run leaves a fresh before/after perf record behind.
//! `cargo run --release -- bench --exp simperf` produces the release
//! version of the same file (CI uploads it as an artifact); this test's
//! record is tagged `"build": "debug"` under `cargo test`.
//!
//! The speedup floor here is deliberately conservative (the measured gap
//! on the saturated configuration is the quadratic-vs-log regime, well
//! above it); set `MOELESS_SKIP_PERF=1` to skip on constrained machines.

use moeless::experiments::simperf;

#[test]
fn perf_trajectory_beats_reference_and_records_bench_sim_json() {
    if std::env::var("MOELESS_SKIP_PERF").is_ok() {
        eprintln!("perf_trajectory skipped (MOELESS_SKIP_PERF set)");
        return;
    }
    let quick = simperf::measure_scale("quick");
    let saturated = simperf::measure_scale("saturated");

    // The saturated drain is the churn regime: preemption/resume must
    // actually fire or the configuration is mis-sized.
    assert!(
        saturated.drain_current.preemptions > 100,
        "saturated config must churn: {} preemptions",
        saturated.drain_current.preemptions
    );
    assert_eq!(saturated.drain_current.completed, 2500, "every request drains");

    let speedup = saturated.drain_speedup();
    assert!(
        speedup >= 1.5,
        "optimized core must beat the pre-PR4 reference on the saturated drain \
         (baseline {:.3}s vs current {:.3}s = {speedup:.2}x)",
        saturated.drain_baseline.wall_s,
        saturated.drain_current.wall_s,
    );

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    simperf::write_bench_json(&path, &[quick, saturated]).unwrap();
    eprintln!(
        "perf_trajectory: saturated speedup {speedup:.2}x \
         (baseline {:.3}s -> current {:.3}s); recorded {}",
        saturated.drain_baseline.wall_s,
        saturated.drain_current.wall_s,
        path.display()
    );
}
