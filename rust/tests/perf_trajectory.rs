//! Perf-trajectory guard + recorder.
//!
//! Measures the frozen pre-PR-4 reference core against the optimized
//! core (same machine, same process) at the quick and saturated scales,
//! asserts the optimized core wins on the saturated drain, runs the
//! driver duel (fixed-cadence lockstep stepper vs event/jump driver) on
//! the 10⁶-request sparse mega drain and asserts the event driver wins
//! ≥2×, and records the numbers to `BENCH_sim.json`
//! (`moeless.simperf/v2`) at the repository root — so every tier-1 run
//! leaves a fresh before/after perf record behind.
//! `cargo run --release -- bench --exp simperf` produces the release
//! version of the same file (CI uploads it as an artifact); this test's
//! record is tagged `"build": "debug"` under `cargo test`.
//!
//! The speedup floor here is deliberately conservative (the measured gap
//! on the saturated configuration is the quadratic-vs-log regime, well
//! above it); set `MOELESS_SKIP_PERF=1` to skip on constrained machines.

use moeless::experiments::simperf;

#[test]
fn perf_trajectory_beats_reference_and_records_bench_sim_json() {
    if std::env::var("MOELESS_SKIP_PERF").is_ok() {
        eprintln!("perf_trajectory skipped (MOELESS_SKIP_PERF set)");
        return;
    }
    let quick = simperf::measure_scale("quick");
    let saturated = simperf::measure_scale("saturated");

    // The saturated drain is the churn regime: preemption/resume must
    // actually fire or the configuration is mis-sized.
    assert!(
        saturated.drain_current.preemptions > 100,
        "saturated config must churn: {} preemptions",
        saturated.drain_current.preemptions
    );
    assert_eq!(saturated.drain_current.completed, 2500, "every request drains");

    let speedup = saturated.drain_speedup();
    assert!(
        speedup >= 1.5,
        "optimized core must beat the pre-PR4 reference on the saturated drain \
         (baseline {:.3}s vs current {:.3}s = {speedup:.2}x)",
        saturated.drain_baseline.wall_s,
        saturated.drain_current.wall_s,
    );

    // Driver duel at the ROADMAP's million-request scale: 10⁶ sparse
    // requests, outcomes asserted identical inside measure_driver_scale.
    // The duel traces are overwhelmingly idle virtual time, so the
    // fixed-cadence stepper pays ~6×10⁷ empty polls the event driver
    // skips — the floor is conservative against the measured gap.
    let mega = simperf::measure_driver_scale("driver-mega");
    assert_eq!(mega.event.completed, 1_000_000, "every mega-drain request drains");
    assert!(
        mega.event.preemptions > 0,
        "mega config must churn inside each burst (KV budget below burst demand)"
    );
    let duel_speedup = mega.speedup();
    assert!(
        duel_speedup >= 2.0,
        "event driver must beat the fixed-cadence stepper on the sparse mega drain \
         (lockstep {:.3}s vs event {:.3}s = {duel_speedup:.2}x)",
        mega.lockstep.wall_s,
        mega.event.wall_s,
    );

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    simperf::write_bench_json(&path, &[quick, saturated], &[mega]).unwrap();
    eprintln!(
        "perf_trajectory: saturated speedup {speedup:.2}x \
         (baseline {:.3}s -> current {:.3}s); driver duel {duel_speedup:.2}x \
         (lockstep {:.3}s -> event {:.3}s); recorded {}",
        saturated.drain_baseline.wall_s,
        saturated.drain_current.wall_s,
        mega.lockstep.wall_s,
        mega.event.wall_s,
        path.display()
    );
}
