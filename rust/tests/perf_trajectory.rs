//! Perf-trajectory guard + recorder.
//!
//! Measures the frozen pre-PR-4 reference core against the optimized
//! core (same machine, same process) at the quick and saturated scales,
//! asserts the optimized core wins on the saturated drain, runs the
//! driver duel (fixed-cadence lockstep stepper vs event/jump driver) on
//! the 10⁶-request sparse mega drain and asserts the event driver wins
//! ≥2×, runs the PR-9 arena duel (SoA arena vs the frozen PR-4 AoS core)
//! and asserts the arena wins ≥1.5× on the saturated drain, measures the
//! sequential-vs-sharded end-to-end duel, runs the PR-10 offload duel
//! (predictor-driven prefetch vs demand fetch on an HBM-oversubscribed
//! fleet), and records everything to
//! `BENCH_sim.json` (`moeless.simperf/v4`) at the repository root — so
//! every tier-1 run leaves a fresh before/after perf record behind.
//! `cargo run --release -- bench --exp simperf` produces the release
//! version of the same file (CI uploads it as an artifact); this test's
//! record is tagged `"build": "debug"` under `cargo test`.
//!
//! The speedup floors here are deliberately conservative (the measured
//! gaps are regime changes — quadratic-vs-log scans, O(total)-vs-
//! O(in-flight) maps — well above them); set `MOELESS_SKIP_PERF=1` to
//! skip on constrained machines.

use moeless::experiments::simperf;

#[test]
fn perf_trajectory_beats_reference_and_records_bench_sim_json() {
    if std::env::var("MOELESS_SKIP_PERF").is_ok() {
        eprintln!("perf_trajectory skipped (MOELESS_SKIP_PERF set)");
        return;
    }
    let quick = simperf::measure_scale("quick");
    let saturated = simperf::measure_scale("saturated");

    // The saturated drain is the churn regime: preemption/resume must
    // actually fire or the configuration is mis-sized.
    assert!(
        saturated.drain_current.preemptions > 100,
        "saturated config must churn: {} preemptions",
        saturated.drain_current.preemptions
    );
    assert_eq!(saturated.drain_current.completed, 2500, "every request drains");

    let speedup = saturated.drain_speedup();
    assert!(
        speedup >= 1.5,
        "optimized core must beat the pre-PR4 reference on the saturated drain \
         (baseline {:.3}s vs current {:.3}s = {speedup:.2}x)",
        saturated.drain_baseline.wall_s,
        saturated.drain_current.wall_s,
    );

    // Driver duel at the ROADMAP's million-request scale: 10⁶ sparse
    // requests, outcomes asserted identical inside measure_driver_scale.
    // The duel traces are overwhelmingly idle virtual time, so the
    // fixed-cadence stepper pays ~6×10⁷ empty polls the event driver
    // skips — the floor is conservative against the measured gap.
    let mega = simperf::measure_driver_scale("driver-mega");
    assert_eq!(mega.event.completed, 1_000_000, "every mega-drain request drains");
    assert!(
        mega.event.preemptions > 0,
        "mega config must churn inside each burst (KV budget below burst demand)"
    );
    let duel_speedup = mega.speedup();
    assert!(
        duel_speedup >= 2.0,
        "event driver must beat the fixed-cadence stepper on the sparse mega drain \
         (lockstep {:.3}s vs event {:.3}s = {duel_speedup:.2}x)",
        mega.lockstep.wall_s,
        mega.event.wall_s,
    );

    // Arena duel (PR 9): the SoA arena against the frozen PR-4 AoS core
    // on the same saturated churn drain. The PR-4 core carries every
    // retired request in its locator map and moves whole sequence
    // structs through its index maps; the arena's maps are O(in-flight)
    // over u32 slots. Outcomes asserted identical inside
    // measure_soa_scale.
    let soa_quick = simperf::measure_soa_scale("quick");
    let soa_saturated = simperf::measure_soa_scale("saturated");
    let soa_mega = simperf::measure_soa_scale("driver-mega");
    assert_eq!(soa_mega.arena.completed, 1_000_000, "every mega request drains via arena");
    let arena_speedup = soa_saturated.speedup();
    assert!(
        arena_speedup >= 1.5,
        "arena core must beat the frozen PR-4 core on the saturated drain \
         (pr4 {:.3}s vs arena {:.3}s = {arena_speedup:.2}x)",
        soa_saturated.pr4.wall_s,
        soa_saturated.arena.wall_s,
    );

    // Shard duel (PR 9): sequential vs 2-thread sharded end-to-end
    // disaggregated sims, outcomes bit-asserted inside
    // measure_shard_scale. The quick sim is too small for a wall-clock
    // win to be reliable under `cargo test`, so only equivalence is
    // gated here; the release bench records the honest speedups.
    let shards: Vec<_> = ["quick", "medium"]
        .into_iter()
        .filter_map(simperf::measure_shard_scale)
        .collect();
    assert!(!shards.is_empty(), "at least one shard-duel scale must run");

    // Offload duel (PR 10): prefetch vs demand fetch on the fleet with
    // expert HBM capped at half the expert set. Both arms replay the
    // identical trace; the demand arm must pay fetch stalls (nothing is
    // overlapped), and prefetch must never stall *more*.
    let offloads: Vec<_> = ["quick", "medium"]
        .into_iter()
        .filter_map(simperf::measure_offload_scale)
        .collect();
    assert!(!offloads.is_empty(), "at least one offload-duel scale must run");
    for o in &offloads {
        assert!(o.demand.stall_ms > 0.0, "{}: demand fetch must pay stalls", o.scale);
        assert!(
            o.prefetch.stall_ms <= o.demand.stall_ms,
            "{}: prefetch stall {:.1}ms must not exceed demand stall {:.1}ms",
            o.scale,
            o.prefetch.stall_ms,
            o.demand.stall_ms,
        );
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sim.json");
    simperf::write_bench_json(
        &path,
        &[quick, saturated],
        &[mega],
        &[soa_quick, soa_saturated, soa_mega],
        &shards,
        &offloads,
    )
    .unwrap();
    eprintln!(
        "perf_trajectory: saturated speedup {speedup:.2}x; driver duel {duel_speedup:.2}x; \
         arena duel {arena_speedup:.2}x (pr4 {:.3}s -> arena {:.3}s); recorded {}",
        soa_saturated.pr4.wall_s,
        soa_saturated.arena.wall_s,
        path.display()
    );
}

#[test]
fn million_request_streaming_run_stays_in_flight_bounded() {
    // The PR-9 memory claim, asserted directly: a 10⁶-request drain in
    // streaming-records mode holds O(in-flight) state — no per-request
    // vectors, a drained locator, a slot arena sized to the in-flight
    // peak, and the retired-id set folded into one interval.
    if std::env::var("MOELESS_SKIP_PERF").is_ok() {
        eprintln!("streaming memory test skipped (MOELESS_SKIP_PERF set)");
        return;
    }
    use moeless::router::Batcher;
    let cfg = simperf::driver_drain_config("driver-mega");
    let mut b = Batcher::with_limits(cfg.limits).with_streaming_records();
    b.enqueue(&cfg.trace);
    let mut clock = 0.0f64;
    let mut guard = 0u64;
    while !b.idle() {
        match b.next_iteration(clock) {
            Some(_) => {
                b.complete_iteration(clock + cfg.iter_s);
                clock += cfg.iter_s;
            }
            None => {
                let next = b.next_arrival().unwrap_or(clock);
                clock = if next > clock { next } else { clock + cfg.iter_s };
            }
        }
        guard += 1;
        assert!(guard < 200_000_000, "streaming mega drain stopped making progress");
    }
    assert_eq!(b.completed, 1_000_000, "every request drains");
    // No per-request vector was ever materialized (capacity, not just
    // length: a push-then-clear would leave the allocation behind).
    assert!(b.finished.is_empty() && b.finished.capacity() == 0);
    assert!(b.ttft_ms.is_empty() && b.ttft_ms.capacity() == 0);
    assert!(b.e2e_ms.is_empty() && b.e2e_ms.capacity() == 0);
    // The locator holds only live sequences: zero after drain.
    assert_eq!(b.locator_len(), 0, "locator must be O(in-flight)");
    // Contiguous ids retire into a single merged interval run.
    assert_eq!(b.retired_runs(), 1, "retired set must fold into one run");
    // The slot arena is sized to the in-flight peak, not the trace.
    let (live, capacity) = b.arena_slots();
    assert_eq!(live, 0);
    assert!(capacity < 5000, "arena capacity {capacity} is not O(in-flight)");
    // The sketches carried all 10⁶ retirements in O(1) space.
    assert_eq!(b.e2e_sketch.len(), 1_000_000);
    let bytes = b.approx_state_bytes();
    assert!(bytes < 2_000_000, "terminal state {bytes} B is not O(in-flight)");
}
