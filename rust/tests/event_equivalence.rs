//! Golden-equivalence suite for the event-heap clock driver (PR 7).
//!
//! The event driver must be *behavior-preserving* against the frozen
//! PR-4 lockstep loop: both drive the same `SimState` iteration methods,
//! so every admission, preemption victim, iteration composition, layer
//! forward time and billing entry must come out bit-for-bit identical —
//! the only thing the drivers are allowed to differ on is how they find
//! the next instant. This suite runs identical configurations under both
//! `DriverKind`s for the colocated, KV-pressure, chunked and
//! disaggregated shapes (plus the `max_iterations` cap and a randomized
//! differential sweep) and asserts full-report equality.
//!
//! PR 9 adds a second equivalence axis on the same reports: intra-run
//! sharding (`shard_threads > 1`) fans the disaggregated pools and the
//! per-layer load finishing across scoped workers, with RNG draws kept
//! sequential and pool outputs merged in the sequential order — so a
//! sharded run must be bit-identical to the `shard_threads = 1` run, for
//! every shape and thread count (the `sharded_*` tests below).
//!
//! Why bit-for-bit is achievable and not merely approximate: the event
//! driver commits an iteration at `clock + pre_ms.max(dec_ms) / 1e3` by
//! popping the later of two per-pool completion events pushed at
//! `clock + pre_ms / 1e3` and `clock + dec_ms / 1e3`. `f64::max` returns
//! one of its operands exactly and `x -> clock + x / 1e3` is monotone,
//! so the later pop instant is the same f64 the lockstep loop computes.
//! Idle jumps reuse the shared `idle_wakeup` decision function verbatim.

use moeless::baselines::PolicyKind;
use moeless::config::{ClusterSpec, DatasetSpec, DisaggSpec, ModelSpec};
use moeless::metrics::RunReport;
use moeless::sim::multimodel::{run_multimodel, MmConfig};
use moeless::sim::{run, DriverKind, SimConfig};
use moeless::util::quickcheck::property;
use moeless::workload::ModelCatalog;

fn base_cfg(policy: PolicyKind) -> SimConfig {
    let mut cfg = SimConfig::new(ModelSpec::mixtral_8x7b(), DatasetSpec::lmsys(), policy);
    cfg.duration_s = 20.0;
    cfg.base_rps = 4.0;
    cfg.seed = 7;
    cfg
}

/// Run one configuration under both drivers.
fn run_both(cfg: &SimConfig) -> (RunReport, RunReport) {
    let mut ev_cfg = cfg.clone();
    ev_cfg.driver = DriverKind::Event;
    let mut lock_cfg = cfg.clone();
    lock_cfg.driver = DriverKind::Lockstep;
    (run(&ev_cfg), run(&lock_cfg))
}

/// Full-report equality, floats by bit pattern. `wall_s` is the one
/// field legitimately allowed to differ (host time, not sim state).
fn assert_bit_identical(label: &str, ev: &RunReport, lock: &RunReport) {
    assert_eq!(ev.driver, "event", "{label}");
    assert_eq!(lock.driver, "lockstep", "{label}");
    assert_outcomes_bit_identical(label, ev, lock);
}

/// The driver-agnostic core of [`assert_bit_identical`]: every outcome
/// field bit-equal (also used by the PR-9 sharded-vs-sequential leg,
/// where both reports come from the same driver).
fn assert_outcomes_bit_identical(label: &str, ev: &RunReport, lock: &RunReport) {
    // Per-request records carry every TTFT/TPOT/e2e timestamp: this is
    // the strongest single assertion.
    assert_eq!(ev.requests, lock.requests, "{label}: per-request records diverged");
    assert_eq!(ev.layer_forward, lock.layer_forward, "{label}: layer forwards diverged");
    assert_eq!(ev.ttft_ms, lock.ttft_ms, "{label}: ttft stream diverged");
    // Scheduler ledger.
    assert_eq!(ev.iterations, lock.iterations, "{label}: iterations");
    assert_eq!(ev.completed_requests, lock.completed_requests, "{label}: completed");
    assert_eq!(ev.preemptions, lock.preemptions, "{label}: preemptions");
    assert_eq!(ev.resumes, lock.resumes, "{label}: resumes");
    assert_eq!(ev.rejected_requests, lock.rejected_requests, "{label}: rejected");
    assert_eq!(ev.delayed_admissions, lock.delayed_admissions, "{label}: delayed");
    assert_eq!(ev.tokens_processed, lock.tokens_processed, "{label}: tokens");
    assert_eq!(ev.tokens_recomputed, lock.tokens_recomputed, "{label}: recompute");
    assert_eq!(ev.prefill_chunks, lock.prefill_chunks, "{label}: chunks");
    assert_eq!(ev.cold_starts, lock.cold_starts, "{label}: cold starts");
    // Billing and accounting, bitwise.
    assert_eq!(
        ev.cost_gb_s.to_bits(),
        lock.cost_gb_s.to_bits(),
        "{label}: cost_gb_s {} vs {}",
        ev.cost_gb_s,
        lock.cost_gb_s
    );
    assert_eq!(
        ev.dollar_cost.to_bits(),
        lock.dollar_cost.to_bits(),
        "{label}: dollar_cost {} vs {}",
        ev.dollar_cost,
        lock.dollar_cost
    );
    assert_eq!(
        ev.residency_gb_s.to_bits(),
        lock.residency_gb_s.to_bits(),
        "{label}: residency_gb_s"
    );
    assert_eq!(
        ev.kv_transfer_gb.to_bits(),
        lock.kv_transfer_gb.to_bits(),
        "{label}: kv_transfer_gb"
    );
    assert_eq!(
        ev.sim_duration_s.to_bits(),
        lock.sim_duration_s.to_bits(),
        "{label}: sim_duration_s {} vs {}",
        ev.sim_duration_s,
        lock.sim_duration_s
    );
    // Per-GPU attribution (exact f64 streams, so Vec equality is exact).
    assert_eq!(ev.gpu_tokens, lock.gpu_tokens, "{label}: gpu_tokens diverged");
    assert_eq!(ev.gpu_busy_ms, lock.gpu_busy_ms, "{label}: gpu_busy_ms diverged");
}

#[test]
fn colocated_event_matches_lockstep() {
    let (ev, lock) = run_both(&base_cfg(PolicyKind::Moeless));
    assert!(ev.completed_requests > 0, "colocated: run must do work");
    assert_bit_identical("colocated", &ev, &lock);
}

#[test]
fn kv_pressure_event_matches_lockstep() {
    // A tight KV carve-out: preemption/resume churn and delayed
    // admissions exercise the requeue paths under both drivers.
    let mut cfg = base_cfg(PolicyKind::Moeless);
    cfg.base_rps = 6.0;
    cfg.kv_budget_override_gb = Some(2.0);
    let (ev, lock) = run_both(&cfg);
    assert!(
        ev.preemptions > 0 || ev.delayed_admissions > 0,
        "kv-pressure: config must create pressure"
    );
    assert_bit_identical("kv-pressure", &ev, &lock);
}

#[test]
fn chunked_event_matches_lockstep() {
    let mut cfg = base_cfg(PolicyKind::Moeless);
    cfg.prefill_chunk_tokens = 256;
    let (ev, lock) = run_both(&cfg);
    assert!(ev.prefill_chunks > 0, "chunked: chunks must land");
    assert_bit_identical("chunked", &ev, &lock);
}

#[test]
fn disaggregated_event_matches_lockstep() {
    // Two pools advancing off per-pool completion events, plus KV
    // handoffs over a slow link whose completion wake-ups can land past
    // the horizon — the corner the event heap must not reorder.
    let mut cfg = base_cfg(PolicyKind::Moeless);
    cfg.prefill_chunk_tokens = 128;
    cfg.kv_budget_override_gb = Some(1.5);
    cfg.disagg = Some(DisaggSpec { link_gbps: 0.05, ..DisaggSpec::even_split(&cfg.cluster) });
    let (ev, lock) = run_both(&cfg);
    assert!(ev.kv_transfer_gb > 0.0, "disagg: handoffs must move KV");
    assert_bit_identical("disagg", &ev, &lock);
}

#[test]
fn max_iterations_cap_event_matches_lockstep() {
    // The cap stops the run mid-stream: both drivers must stop after the
    // same iteration, with the same partial ledger.
    let mut cfg = base_cfg(PolicyKind::Megatron);
    cfg.max_iterations = 40;
    let (ev, lock) = run_both(&cfg);
    assert_eq!(ev.iterations, 40, "cap must bind at this load");
    assert_bit_identical("max-iterations", &ev, &lock);
}

#[test]
fn serverless_policy_event_matches_lockstep() {
    // MoEless-style serverless billing flows through the same pinned
    // instants; async-EP covers the serverful no-barrier path.
    let (ev, lock) = run_both(&base_cfg(PolicyKind::AsyncEp));
    assert_bit_identical("async-ep", &ev, &lock);
}

// ---------------------------------------------------------------------------
// Multi-model colocation (PR 8): the colocation layer runs on the same
// generic event queue, with its own lockstep oracle replaying the heap's
// `(t_bits, seq)` order by linear scan — same bit-for-bit bar.
// ---------------------------------------------------------------------------

fn mm_cfg(n_models: usize, seed: u64) -> MmConfig {
    let mut cfg =
        MmConfig::new(ModelCatalog::zipf(n_models, 1.2, seed), DatasetSpec::lmsys());
    cfg.duration_s = 20.0;
    cfg.base_rps = 4.0;
    cfg.seed = seed;
    cfg
}

/// Run one colocation configuration under both drivers.
fn run_mm_both(cfg: &MmConfig) -> (RunReport, RunReport) {
    let mut ev_cfg = cfg.clone();
    ev_cfg.driver = DriverKind::Event;
    let mut lock_cfg = cfg.clone();
    lock_cfg.driver = DriverKind::Lockstep;
    (run_multimodel(&ev_cfg), run_multimodel(&lock_cfg))
}

/// Colocation reports add per-model lanes on top of the single-model
/// fields; compare those too (every lane f64 is an exact event-time
/// derivative, so `ModelLane` equality is exact).
fn assert_mm_bit_identical(label: &str, ev: &RunReport, lock: &RunReport) {
    assert_bit_identical(label, ev, lock);
    assert_eq!(ev.per_model, lock.per_model, "{label}: per-model lanes diverged");
}

#[test]
fn multimodel_event_matches_lockstep() {
    let (ev, lock) = run_mm_both(&mm_cfg(8, 7));
    assert!(ev.completed_requests > 0, "multimodel: run must do work");
    assert!(ev.cold_starts > 0, "multimodel: catalog must cold-start");
    assert_mm_bit_identical("multimodel", &ev, &lock);
}

#[test]
fn multimodel_contended_event_matches_lockstep() {
    // HBM contention: a 2-GPU fleet under a 10-model catalog forces the
    // LRU eviction and rejection paths under both drivers.
    let mut cfg = mm_cfg(10, 11);
    cfg.cluster = ClusterSpec::a6000_x8().with_n_gpus(2).with_mem_per_gpu(12.0);
    cfg.base_rps = 6.0;
    let (ev, lock) = run_mm_both(&cfg);
    assert!(ev.cold_starts > 0, "contended: reloads must happen");
    assert_mm_bit_identical("multimodel-contended", &ev, &lock);
}

#[test]
fn multimodel_oblivious_event_matches_lockstep() {
    // The A/B ablation leg must be driver-equivalent too, or the
    // regression comparison would be comparing drivers, not policies.
    let mut cfg = mm_cfg(8, 13);
    cfg.locality = false;
    let (ev, lock) = run_mm_both(&cfg);
    assert_mm_bit_identical("multimodel-oblivious", &ev, &lock);
}

#[test]
fn catalog_of_one_is_bit_for_bit_the_single_model_path() {
    // The tentpole no-op guarantee: a catalog of one IS the existing
    // single-model simulation under both drivers — same frozen numbers
    // the rest of this suite pins, plus exactly one derived lane.
    for driver in [DriverKind::Event, DriverKind::Lockstep] {
        let mut single = base_cfg(PolicyKind::Moeless);
        single.driver = driver;
        let baseline = run(&single);

        let mut cfg =
            MmConfig::new(ModelCatalog::single(single.model.clone()), single.dataset.clone());
        cfg.cluster = single.cluster.clone();
        cfg.scenario = single.scenario.clone();
        cfg.duration_s = single.duration_s;
        cfg.base_rps = single.base_rps;
        cfg.seed = single.seed;
        cfg.driver = driver;
        let mm = run_multimodel(&cfg);

        // Every single-model field bit-identical to today's path...
        assert_eq!(mm.requests, baseline.requests, "{driver:?}: requests diverged");
        assert_eq!(mm.layer_forward, baseline.layer_forward, "{driver:?}");
        assert_eq!(mm.iterations, baseline.iterations, "{driver:?}");
        assert_eq!(mm.dollar_cost.to_bits(), baseline.dollar_cost.to_bits(), "{driver:?}");
        assert_eq!(mm.cost_gb_s.to_bits(), baseline.cost_gb_s.to_bits(), "{driver:?}");
        assert_eq!(
            mm.sim_duration_s.to_bits(),
            baseline.sim_duration_s.to_bits(),
            "{driver:?}"
        );
        assert_eq!(mm.gpu_tokens, baseline.gpu_tokens, "{driver:?}");
        assert_eq!(mm.policy, baseline.policy, "{driver:?}: same policy label");
        // ...plus the one additive lane.
        assert!(baseline.per_model.is_empty(), "single-model runs carry no lanes");
        assert_eq!(mm.per_model.len(), 1, "{driver:?}: catalog-of-one adds one lane");
        assert_eq!(mm.per_model[0].completed, baseline.completed_requests, "{driver:?}");
    }
}

#[test]
fn randomized_multimodel_differential_event_matches_lockstep() {
    // Fixed-seed randomized sweep over catalog size × skew × load ×
    // placement policy × fleet size. Short traces: the lockstep oracle is
    // O(n²) by design (it exists to pin the heap).
    property(20, |g| {
        let mut cfg = mm_cfg(g.usize_in(2, 12), g.usize_in(0, 1000) as u64);
        cfg.catalog = ModelCatalog::zipf(cfg.catalog.len(), g.f64_in(0.5, 2.0), cfg.seed);
        cfg.duration_s = g.f64_in(4.0, 12.0);
        cfg.base_rps = g.f64_in(1.0, 6.0);
        cfg.locality = g.bool();
        if g.bool() {
            cfg.cluster = ClusterSpec::a6000_x8().with_n_gpus(g.usize_in(1, 4));
        }
        let (ev, lock) = run_mm_both(&cfg);
        assert_mm_bit_identical("multimodel-randomized", &ev, &lock);
    });
}

// ---------------------------------------------------------------------------
// Intra-run sharding (PR 9): `shard_threads = 1` is the exact sequential
// path; any other count must reproduce it bit for bit.
// ---------------------------------------------------------------------------

/// Run one configuration sharded across `threads` workers and
/// sequentially; returns (sharded, sequential).
fn run_sharded_both(cfg: &SimConfig, threads: usize) -> (RunReport, RunReport) {
    let mut sh_cfg = cfg.clone();
    sh_cfg.shard_threads = threads;
    let mut seq_cfg = cfg.clone();
    seq_cfg.shard_threads = 1;
    (run(&sh_cfg), run(&seq_cfg))
}

#[test]
fn sharded_colocated_matches_sequential() {
    let (sh, seq) = run_sharded_both(&base_cfg(PolicyKind::Moeless), 3);
    assert!(sh.completed_requests > 0, "sharded-colocated: run must do work");
    assert_outcomes_bit_identical("sharded-colocated", &sh, &seq);
}

#[test]
fn sharded_kv_pressure_matches_sequential() {
    let mut cfg = base_cfg(PolicyKind::Moeless);
    cfg.base_rps = 6.0;
    cfg.kv_budget_override_gb = Some(2.0);
    let (sh, seq) = run_sharded_both(&cfg, 2);
    assert!(
        sh.preemptions > 0 || sh.delayed_admissions > 0,
        "sharded-kv-pressure: config must create pressure"
    );
    assert_outcomes_bit_identical("sharded-kv-pressure", &sh, &seq);
}

#[test]
fn sharded_chunked_matches_sequential() {
    let mut cfg = base_cfg(PolicyKind::Moeless);
    cfg.prefill_chunk_tokens = 256;
    let (sh, seq) = run_sharded_both(&cfg, 4);
    assert!(sh.prefill_chunks > 0, "sharded-chunked: chunks must land");
    assert_outcomes_bit_identical("sharded-chunked", &sh, &seq);
}

#[test]
fn sharded_disaggregated_matches_sequential() {
    // The join2 fan-out proper: both pools run concurrently, outputs
    // merged in the sequential interleave order afterwards.
    let mut cfg = base_cfg(PolicyKind::Moeless);
    cfg.prefill_chunk_tokens = 128;
    cfg.kv_budget_override_gb = Some(1.5);
    cfg.disagg = Some(DisaggSpec { link_gbps: 0.05, ..DisaggSpec::even_split(&cfg.cluster) });
    for threads in [2usize, 4] {
        let (sh, seq) = run_sharded_both(&cfg, threads);
        assert!(sh.kv_transfer_gb > 0.0, "sharded-disagg: handoffs must move KV");
        assert_outcomes_bit_identical(&format!("sharded-disagg x{threads}"), &sh, &seq);
    }
}

#[test]
fn sharded_multimodel_matches_sequential() {
    // Per-GPU placement evaluation fans out in `on_arrival`; the scores
    // land back in GPU order, so placement is thread-count-invariant.
    let mut sh_cfg = mm_cfg(8, 7);
    sh_cfg.shard_threads = 3;
    let sh = run_multimodel(&sh_cfg);
    let seq = run_multimodel(&mm_cfg(8, 7));
    assert!(sh.cold_starts > 0, "sharded-multimodel: catalog must cold-start");
    assert_outcomes_bit_identical("sharded-multimodel", &sh, &seq);
    assert_eq!(sh.per_model, seq.per_model, "sharded-multimodel: lanes diverged");
}

#[test]
fn randomized_sharded_differential_matches_sequential() {
    // Fixed-seed randomized sweep over policy × load × chunking × KV
    // budget × disaggregation × thread count: the sharded run must always
    // be the sequential run, bit for bit.
    property(20, |g| {
        let policy =
            *g.pick(&[PolicyKind::Moeless, PolicyKind::Megatron, PolicyKind::AsyncEp]);
        let mut cfg = base_cfg(policy);
        cfg.duration_s = g.f64_in(4.0, 10.0);
        cfg.base_rps = g.f64_in(1.0, 6.0);
        cfg.seed = g.usize_in(0, 1000) as u64;
        cfg.prefill_chunk_tokens = *g.pick(&[0usize, 128, 256]);
        cfg.driver = *g.pick(&[DriverKind::Event, DriverKind::Lockstep]);
        if g.bool() {
            cfg.kv_budget_override_gb = Some(g.f64_in(1.0, 4.0));
        }
        if g.bool() {
            cfg.disagg = Some(DisaggSpec {
                link_gbps: g.f64_in(0.02, 1.0),
                ..DisaggSpec::even_split(&cfg.cluster)
            });
        }
        let threads = g.usize_in(2, 5);
        let (sh, seq) = run_sharded_both(&cfg, threads);
        assert_outcomes_bit_identical(&format!("sharded-randomized x{threads}"), &sh, &seq);
    });
}

#[test]
fn randomized_differential_event_matches_lockstep() {
    // Fixed-seed randomized sweep over policy × load × chunking × KV
    // budget × disaggregation: any divergence fails with the generating
    // seed printed by the property harness.
    property(30, |g| {
        let policy =
            *g.pick(&[PolicyKind::Moeless, PolicyKind::Megatron, PolicyKind::AsyncEp]);
        let mut cfg = base_cfg(policy);
        cfg.duration_s = g.f64_in(4.0, 12.0);
        cfg.base_rps = g.f64_in(1.0, 6.0);
        cfg.seed = g.usize_in(0, 1000) as u64;
        cfg.prefill_chunk_tokens = *g.pick(&[0usize, 128, 256]);
        if g.bool() {
            cfg.kv_budget_override_gb = Some(g.f64_in(1.0, 4.0));
        }
        if g.bool() {
            cfg.disagg = Some(DisaggSpec {
                link_gbps: g.f64_in(0.02, 1.0),
                ..DisaggSpec::even_split(&cfg.cluster)
            });
        }
        let (ev, lock) = run_both(&cfg);
        assert_bit_identical("randomized", &ev, &lock);
    });
}
