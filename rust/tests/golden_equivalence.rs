//! Golden-equivalence suite for the PR-4 simulation-core rewrite.
//!
//! The allocation-lean, incrementally-indexed batcher must be
//! *behavior-preserving*: identical admissions, identical preemption
//! victims, identical iteration compositions, identical per-request
//! records — bit for bit — against the pre-PR-4 implementation, which is
//! kept frozen as `router::reference`. PR 9 re-indexed the batcher's
//! sequence state into a SoA slab arena (`router::arena`) with the PR-4
//! AoS core frozen verbatim as `router::pr4` — so the suite now drives
//! **three** cores in lockstep over fixed-seed traces for the colocated,
//! chunked and disaggregated configurations (plus KV-pressure variants
//! and a randomized differential sweep) and asserts equality at every
//! step. Against the reference, per-iteration retirement order is
//! representation-defined (multiset compare); against the frozen PR-4
//! core the arena is an exact re-indexing, so every record sequence must
//! match order included.
//!
//! Why this implies RunReport golden equivalence: the simulator's clock
//! advances only by per-layer forward times of the iteration
//! compositions the batcher emits, and the engine arithmetic is
//! untouched by the rewrite — so identical `IterationBatch` sequences
//! imply identical virtual timestamps, hence identical goodput,
//! p99 TTFT/TPOT, preemption counts and `kv_transfer_gb` (the headline
//! numbers). The per-request records asserted here are exactly those
//! inputs.

use moeless::config::DatasetSpec;
use moeless::router::{pr4, reference, BatchLimits, Batcher};
use moeless::util::quickcheck::property;
use moeless::workload::{burst_trace, interference_trace, Scenario, TraceRequest};

/// Drive both cores in lockstep and assert equality at every observation
/// point, then at drain.
fn assert_equivalent(
    label: &str,
    trace: &[TraceRequest],
    limits: BatchLimits,
    link_gbps: Option<f64>,
    iter_s: f64,
) {
    let mut new_b = Batcher::with_limits(limits);
    let mut pr4_b = pr4::Batcher::with_limits(limits);
    let mut old_b = reference::Batcher::with_limits(limits);
    if let Some(l) = link_gbps {
        new_b = new_b.with_transfer_link(l);
        pr4_b = pr4_b.with_transfer_link(l);
        old_b = old_b.with_transfer_link(l);
    }
    new_b.enqueue(trace);
    pr4_b.enqueue(trace);
    old_b.enqueue(trace);

    let mut clock = 0.0f64;
    let mut guard = 0u64;
    loop {
        assert_eq!(new_b.idle(), old_b.idle(), "{label}: idle diverged at t={clock}");
        assert_eq!(new_b.idle(), pr4_b.idle(), "{label}: idle diverged from pr4 at t={clock}");
        if new_b.idle() {
            break;
        }
        let a = new_b.next_iteration(clock);
        let p = pr4_b.next_iteration(clock);
        let b = old_b.next_iteration(clock);
        assert_eq!(a, b, "{label}: iteration batch diverged at t={clock}");
        assert_eq!(a, p, "{label}: iteration batch diverged from pr4 at t={clock}");
        assert_eq!(
            new_b.kv_tokens_in_use(),
            old_b.kv_tokens_in_use(),
            "{label}: KV ledger diverged at t={clock}"
        );
        assert_eq!(new_b.kv_tokens_in_use(), pr4_b.kv_tokens_in_use(), "{label}: t={clock}");
        assert_eq!(new_b.queue_depth(), old_b.queue_depth(), "{label}: t={clock}");
        assert_eq!(new_b.queue_depth(), pr4_b.queue_depth(), "{label}: t={clock}");
        assert_eq!(new_b.in_flight(), old_b.in_flight(), "{label}: t={clock}");
        assert_eq!(new_b.in_flight(), pr4_b.in_flight(), "{label}: t={clock}");
        assert_eq!(new_b.transferring_len(), old_b.transferring_len(), "{label}: t={clock}");
        assert_eq!(new_b.transferring_len(), pr4_b.transferring_len(), "{label}: t={clock}");
        match a {
            Some(_) => {
                new_b.complete_iteration(clock + iter_s);
                pr4_b.complete_iteration(clock + iter_s);
                old_b.complete_iteration(clock + iter_s);
            }
            None => {
                let (na, oa) = (new_b.next_arrival(), old_b.next_arrival());
                assert_eq!(na, oa, "{label}: next_arrival diverged at t={clock}");
                assert_eq!(na, pr4_b.next_arrival(), "{label}: next_arrival pr4 t={clock}");
                clock = na.unwrap_or(clock).max(clock);
            }
        }
        clock += iter_s;
        guard += 1;
        assert!(guard < 1_000_000, "{label}: drain must terminate");
    }

    // The arena is an exact re-indexing of the frozen PR-4 core: every
    // counter and every record sequence matches order included.
    assert_eq!(new_b.admitted, pr4_b.admitted, "{label} vs pr4");
    assert_eq!(new_b.completed, pr4_b.completed, "{label} vs pr4");
    assert_eq!(new_b.rejected, pr4_b.rejected, "{label} vs pr4");
    assert_eq!(new_b.delayed_admissions, pr4_b.delayed_admissions, "{label} vs pr4");
    assert_eq!(new_b.preemptions, pr4_b.preemptions, "{label} vs pr4");
    assert_eq!(new_b.resumes, pr4_b.resumes, "{label} vs pr4");
    assert_eq!(new_b.tokens_recomputed, pr4_b.tokens_recomputed, "{label} vs pr4");
    assert_eq!(new_b.kv_transfer_bytes, pr4_b.kv_transfer_bytes, "{label} vs pr4");
    assert_eq!(new_b.ttft_ms, pr4_b.ttft_ms, "{label} vs pr4");
    assert_eq!(new_b.e2e_ms, pr4_b.e2e_ms, "{label} vs pr4: retirement order");
    assert_eq!(new_b.finished, pr4_b.finished, "{label} vs pr4: per-request records");

    // Terminal counters: exact.
    assert_eq!(new_b.admitted, old_b.admitted, "{label}");
    assert_eq!(new_b.completed, old_b.completed, "{label}");
    assert_eq!(new_b.rejected, old_b.rejected, "{label}");
    assert_eq!(new_b.delayed_admissions, old_b.delayed_admissions, "{label}");
    assert_eq!(new_b.preemptions, old_b.preemptions, "{label}");
    assert_eq!(new_b.resumes, old_b.resumes, "{label}");
    assert_eq!(new_b.chunks_landed, old_b.chunks_landed, "{label}");
    assert_eq!(new_b.tokens_prefilled, old_b.tokens_prefilled, "{label}");
    assert_eq!(new_b.tokens_decoded, old_b.tokens_decoded, "{label}");
    assert_eq!(new_b.tokens_recomputed, old_b.tokens_recomputed, "{label}");
    assert_eq!(new_b.kv_transfer_bytes, old_b.kv_transfer_bytes, "{label}");

    // TTFT is recorded in prefill-completion order, which both cores
    // share (FIFO by admission): exact, order included.
    assert_eq!(new_b.ttft_ms, old_b.ttft_ms, "{label}");

    // Retirement order *within* one iteration is representation-defined
    // (age order vs. scan order), so per-request populations compare as
    // multisets / by id — the values must be bit-identical.
    let mut new_e2e = new_b.e2e_ms.clone();
    let mut old_e2e = old_b.e2e_ms.clone();
    new_e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
    old_e2e.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert_eq!(new_e2e, old_e2e, "{label}");

    let mut new_recs = new_b.finished.clone();
    let mut old_recs = old_b.finished.clone();
    new_recs.sort_by_key(|r| r.id);
    old_recs.sort_by_key(|r| r.id);
    assert_eq!(new_recs, old_recs, "{label}: per-request records diverged");
}

fn kv_limits(max_batch: usize, budget_tokens: f64, chunk: usize) -> BatchLimits {
    BatchLimits {
        max_batch_tokens: max_batch,
        kv_budget_bytes: budget_tokens,
        kv_bytes_per_token: 1.0,
        prefill_chunk_tokens: chunk,
    }
}

#[test]
fn colocated_unconstrained_matches_reference() {
    let trace = Scenario::bursty().generate(&DatasetSpec::lmsys(), 40.0, 6.0, 7);
    assert_equivalent("colocated", &trace, BatchLimits::default(), None, 0.08);
}

#[test]
fn colocated_kv_pressure_matches_reference() {
    // The PR-2 oversubscription shape: simultaneous burst far over the
    // budget — continuous preemption/resume churn.
    let trace = burst_trace(24, 0.0, 400, 120);
    assert_equivalent("kv-pressure", &trace, kv_limits(4096, 4000.0, 0), None, 0.05);
}

#[test]
fn chunked_matches_reference() {
    let trace = Scenario::bursty().generate(&DatasetSpec::lmsys(), 30.0, 6.0, 3);
    assert_equivalent("chunked", &trace, kv_limits(0, 8000.0, 256), None, 0.08);
}

#[test]
fn chunked_interference_tight_budget_matches_reference() {
    // Long prompts + steady decodes under a tight budget: mid-prefill
    // preemption, resume-from-last-chunk, the one-token headroom rule.
    let trace = interference_trace(20.0, 10.0, 32, 6, 5.0, 2048, 8);
    assert_equivalent("chunked-tight", &trace, kv_limits(0, 6000.0, 512), None, 0.05);
}

#[test]
fn disaggregated_handoff_matches_reference() {
    // Phase handoffs over a slow link: transferring holds KV, TTFT is
    // delayed, the transfer completion wakes the clock.
    let trace = burst_trace(8, 0.0, 400, 30);
    let limits = BatchLimits {
        max_batch_tokens: 0,
        kv_budget_bytes: f64::INFINITY,
        kv_bytes_per_token: 1024.0,
        prefill_chunk_tokens: 128,
    };
    assert_equivalent("disagg", &trace, limits, Some(0.01), 0.05);
}

#[test]
fn disaggregated_kv_pressure_matches_reference() {
    // The nastiest corner: chunked prefill + KV gating + in-transit
    // handoff KV holding the budget (the oversized-alone override and
    // the transfer wake-up interact here).
    let trace = burst_trace(16, 0.0, 300, 40);
    let limits = BatchLimits {
        max_batch_tokens: 0,
        kv_budget_bytes: 3_000_000.0,
        kv_bytes_per_token: 1024.0,
        prefill_chunk_tokens: 256,
    };
    assert_equivalent("disagg-tight", &trace, limits, Some(0.005), 0.05);
}

#[test]
fn uniform_vec_gpu_spec_matches_legacy_uniform_run() {
    // The heterogeneous-resource refactor's golden: a uniform fleet
    // expressed three ways — the preset, the uniform JSON shorthand, and
    // an explicit per-GPU array of identical devices — must produce
    // bit-identical end-to-end runs (same iteration compositions, same
    // per-request records, same cost).
    use moeless::baselines::PolicyKind;
    use moeless::config::{ClusterSpec, ModelSpec};
    use moeless::sim::{run, SimConfig};
    use moeless::util::json::Json;

    let entry = r#"{"mem_gb": 48, "tflops": 155, "hbm_gbps": 768, "cost_per_hour": 0.8}"#;
    let arr = format!(r#"{{"gpus": [{}]}}"#, [entry; 8].join(","));
    let shorthand = Json::parse(r#"{"n_gpus": 8, "mem_per_gpu_gb": 48}"#).unwrap();
    let per_gpu = Json::parse(&arr).unwrap();

    let mut reports = Vec::new();
    for cluster in [
        ClusterSpec::a6000_x8(),
        ClusterSpec::from_json(&shorthand).unwrap(),
        ClusterSpec::from_json(&per_gpu).unwrap(),
    ] {
        let mut cfg = SimConfig::new(
            ModelSpec::mixtral_8x7b(),
            DatasetSpec::lmsys(),
            PolicyKind::Moeless,
        );
        cfg.cluster = cluster;
        cfg.duration_s = 20.0;
        cfg.base_rps = 4.0;
        cfg.seed = 11;
        cfg.prefill_chunk_tokens = 256;
        reports.push(run(&cfg));
    }
    for r in &reports[1..] {
        assert_eq!(reports[0].requests, r.requests, "per-request records diverged");
        assert_eq!(reports[0].layer_forward, r.layer_forward, "layer forwards diverged");
        assert_eq!(reports[0].cost_gb_s, r.cost_gb_s);
        assert_eq!(reports[0].iterations, r.iterations);
        assert_eq!(reports[0].gpu_tokens, r.gpu_tokens);
    }
    // The capacity-aware flag is a decision-side switch: on a uniform
    // fleet flipping it off must change nothing, bit for bit.
    let mut cfg = SimConfig::new(
        ModelSpec::mixtral_8x7b(),
        DatasetSpec::lmsys(),
        PolicyKind::Moeless,
    );
    cfg.cluster = ClusterSpec::a6000_x8();
    cfg.cluster.capacity_aware = false;
    cfg.duration_s = 20.0;
    cfg.base_rps = 4.0;
    cfg.seed = 11;
    cfg.prefill_chunk_tokens = 256;
    let flipped = run(&cfg);
    assert_eq!(reports[0].requests, flipped.requests);
    assert_eq!(reports[0].layer_forward, flipped.layer_forward);
}

#[test]
fn hetero_json_matches_preset_and_is_deterministic() {
    // A mixed fleet parsed from the per-GPU JSON array equals the
    // equivalent preset run, and hetero runs replay deterministically
    // (the stable-tie-break prerequisite for hetero goldens).
    use moeless::baselines::PolicyKind;
    use moeless::config::{ClusterSpec, ModelSpec};
    use moeless::sim::{run, SimConfig};
    use moeless::util::json::Json;

    let h100 = r#"{"name":"h100","mem_gb":80,"tflops":989,"hbm_gbps":3350,"cost_per_hour":3.9}"#;
    let a6000 = r#"{"name":"a6000","mem_gb":48,"tflops":155,"hbm_gbps":768,"cost_per_hour":0.8}"#;
    let mut entries = vec![h100, h100];
    entries.extend([a6000; 6]);
    let json = Json::parse(&format!(r#"{{"gpus": [{}]}}"#, entries.join(","))).unwrap();
    let parsed = ClusterSpec::from_json(&json).unwrap();

    let mk = |cluster: ClusterSpec| {
        let mut cfg = SimConfig::new(
            ModelSpec::mixtral_8x7b(),
            DatasetSpec::lmsys(),
            PolicyKind::Moeless,
        );
        cfg.cluster = cluster;
        cfg.duration_s = 20.0;
        cfg.base_rps = 4.0;
        cfg.seed = 11;
        cfg
    };
    let via_json = run(&mk(parsed));
    let via_preset = run(&mk(ClusterSpec::hetero_h100_a6000()));
    assert_eq!(via_json.requests, via_preset.requests);
    assert_eq!(via_json.layer_forward, via_preset.layer_forward);
    assert_eq!(via_json.gpu_tokens, via_preset.gpu_tokens);
    let again = run(&mk(ClusterSpec::hetero_h100_a6000()));
    assert_eq!(via_preset.requests, again.requests);
    assert_eq!(via_preset.gpu_busy_ms, again.gpu_busy_ms);
    // The mixed fleet actually engages the capacity-aware path: the
    // H100s carry a disproportionate token share.
    let h100_tokens: f64 = via_preset.gpu_tokens[..2].iter().sum();
    let total: f64 = via_preset.gpu_tokens.iter().sum();
    assert!(total > 0.0);
    assert!(h100_tokens > 2.0 / 8.0 * total, "fast devices absorb an outsized share");
}

#[test]
fn randomized_differential_matches_reference() {
    // Fixed-seed randomized sweep over traces × limits: any divergence
    // between the cores fails with the generating seed.
    property(60, |g| {
        let n = g.usize_in(1, 30);
        let mut arrivals: Vec<f64> = (0..n).map(|_| g.f64_in(0.0, 8.0)).collect();
        arrivals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let trace: Vec<TraceRequest> = arrivals
            .iter()
            .enumerate()
            .map(|(i, &t)| TraceRequest {
                id: i as u64,
                arrival_s: t,
                prompt_tokens: g.usize_in(1, 80),
                output_tokens: g.usize_in(1, 40),
            })
            .collect();
        let budget = if g.bool() { g.usize_in(50, 400) as f64 } else { f64::INFINITY };
        let limits = BatchLimits {
            max_batch_tokens: *g.pick(&[0usize, 64, 256]),
            kv_budget_bytes: budget,
            kv_bytes_per_token: 1.0,
            prefill_chunk_tokens: *g.pick(&[0usize, 16, 64]),
        };
        let link = if g.bool() { Some(1e-7 * g.usize_in(1, 50) as f64) } else { None };
        assert_equivalent("randomized", &trace, limits, link, 0.05);
    });
}
