//! Minimal offline stand-in for the `anyhow` crate.
//!
//! crates.io is unavailable in the build environment, so this vendored
//! shim implements exactly the API surface the `moeless` crate uses:
//! [`Error`], [`Result`], [`Error::msg`], the [`Context`] extension trait
//! (on `Result` and `Option`), and the [`bail!`]/[`anyhow!`] macros. Error
//! chains are flattened into the message at wrap time; that is all the
//! callers ever display.

use std::fmt;

/// A flattened, `String`-backed error value.
///
/// Like the real `anyhow::Error`, this type deliberately does **not**
/// implement `std::error::Error` — that is what makes the blanket
/// `From<E: std::error::Error>` conversion below coherent alongside the
/// standard library's reflexive `From<T> for T`.
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string() }
    }

    /// Build an error from a concrete error value (flattens the message).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string() }
    }

    /// Wrap with an outer context message: `"{context}: {inner}"`.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>`: `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    /// Attach a context message to the error, if any.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message to the error, if any.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::Error::msg(format!($($arg)*)))
    };
}

/// Build a formatted [`Error`] value in place (the non-returning `bail!`).
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::{Context, Error, Result};

    fn fails() -> Result<u32> {
        bail!("boom {}", 7)
    }

    #[test]
    fn bail_formats() {
        assert_eq!(fails().unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn context_wraps_std_errors() {
        let e = std::fs::read("/definitely/not/a/real/path")
            .context("reading cfg")
            .unwrap_err();
        assert!(e.to_string().starts_with("reading cfg: "), "{e}");
    }

    #[test]
    fn with_context_wraps_shim_errors() {
        let r: Result<()> = Err(Error::msg("inner"));
        let e = r.with_context(|| format!("outer {}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "outer 1: inner");
    }

    #[test]
    fn question_mark_converts() {
        fn io_fail() -> Result<Vec<u8>> {
            Ok(std::fs::read("/definitely/not/a/real/path")?)
        }
        assert!(io_fail().is_err());
    }
}
