//! Compilable stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The Tier-A serving path needs native XLA/PJRT libraries that this build
//! environment does not have. This vendored stub keeps the `pjrt` cargo
//! feature *compilable*: it mirrors the exact API surface
//! `moeless::runtime` uses, and every fallible entry point returns a
//! descriptive [`Error`] at runtime, so callers (which already skip
//! gracefully when artifacts are missing) degrade to Tier-B.
//!
//! To run Tier A for real, point the `xla` path dependency in
//! `rust/Cargo.toml` at a real xla-rs checkout with the PJRT CPU plugin.

use std::fmt;
use std::path::Path;

/// Stub error: carries the "built without native XLA" explanation.
#[derive(Clone, Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} unavailable — built without native XLA/PJRT \
         (point rust/vendor/xla at a real xla-rs checkout to run Tier A)"
    )))
}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}

impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        stub("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        stub("PjRtClient::compile")
    }
}

/// Parsed HLO module (stub: parsing always fails).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(_path: P) -> Result<HloModuleProto> {
        stub("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, loaded executable (stub: execution always fails).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _inputs: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        stub("PjRtLoadedExecutable::execute")
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        stub("PjRtBuffer::to_literal_sync")
    }
}

/// A host literal (stub: opaque, all accessors fail).
#[derive(Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        stub("Literal::reshape")
    }

    pub fn shape(&self) -> Result<Shape> {
        stub("Literal::shape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        stub("Literal::to_vec")
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        stub("Literal::to_tuple")
    }
}

/// Array-or-tuple shape of a literal.
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Dimensions of an array-shaped literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn new(dims: Vec<i64>) -> ArrayShape {
        ArrayShape { dims }
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("xla stub"), "{e}");
        assert!(HloModuleProto::from_text_file("/x.hlo").is_err());
    }

    #[test]
    fn shape_accessors() {
        let s = Shape::Array(ArrayShape::new(vec![2, 3]));
        match &s {
            Shape::Array(a) => assert_eq!(a.dims(), &[2, 3]),
            _ => panic!("expected array shape"),
        }
    }
}
