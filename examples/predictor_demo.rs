//! Predictor demo: run the *real* fine-tuned gate-replica predictors
//! (weights trained by `python/compile/finetune.py`) over PJRT on real
//! TinyMoE hidden states, and report measured speculative-prediction
//! accuracy per (layer, distance) — the Tier-A ground truth behind Fig. 7.
//!
//! Run: `cargo run --release --example predictor_demo`

use moeless::config::MoelessParams;
use moeless::model::DecomposedServer;
use moeless::tensor::store::artifacts_dir;
use moeless::util::json::Json;
use moeless::util::rng::Pcg;

fn main() {
    // Measured build-time profile (test split).
    let profile = artifacts_dir().join("predictor_profile.json");
    if let Ok(p) = Json::parse_file(&profile).map_err(|e| eprintln!("{e}")) {
        println!("build-time measured accuracy (finetune.py, 30% held-out):");
        println!("{:>6} {:>4} {:>8} {:>11} {:>10} {:>8}", "layer", "d", "cosine", "pretrained", "finetuned", "promoe");
        for e in p.get("entries").as_arr() {
            println!(
                "{:>6} {:>4} {:>8.3} {:>11.3} {:>10.3} {:>8.3}",
                e.get("layer").as_usize(),
                e.get("distance").as_usize(),
                e.get("cos_sim").as_f64(),
                e.get("acc_pretrained").as_f64(),
                e.get("acc_finetuned").as_f64(),
                e.get("acc_promoe").as_f64()
            );
        }
    }

    // Live: serve with prediction distances 1..3 and report the accuracy
    // the coordinator actually measured while serving.
    for d in 1..=3usize {
        let mut params = MoelessParams::default();
        params.prediction_distance = d;
        let Some(mut srv) = DecomposedServer::open_default(params) else {
            eprintln!("artifacts missing — run `make artifacts` first");
            std::process::exit(1);
        };
        let dims = srv.dims;
        let mut rng = Pcg::seeded(100 + d as u64);
        let mut accs = Vec::new();
        for _ in 0..4 {
            let tokens: Vec<i32> =
                (0..dims.n_tokens()).map(|_| rng.below(dims.vocab) as i32).collect();
            let lens: Vec<usize> =
                (0..dims.batch).map(|_| rng.range(dims.seq / 2, dims.seq + 1)).collect();
            let (_, stats) = srv.forward(&tokens, &lens).expect("forward");
            accs.push(stats.pred_accuracy);
        }
        let mean = accs.iter().sum::<f64>() / accs.len() as f64;
        println!("live serving, d={d}: mean measured load-prediction accuracy {mean:.3}");
    }
    println!("predictor_demo OK");
}
