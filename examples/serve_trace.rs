//! Trace replay example: serve the paper's three MoE models on an
//! Azure-style trace with all four policies and print the Fig. 8/10-style
//! comparison (Tier B).
//!
//! Run: `cargo run --release --example serve_trace [-- --seconds 120 --rps 8]`

use moeless::config::{DatasetSpec, ModelSpec};
use moeless::metrics::reduction_pct;
use moeless::sim::run_paper_set;
use moeless::util::benchkit::series_summary;
use moeless::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let seconds = args.f64("seconds", 90.0);
    let seed = args.u64("seed", 42);

    for model in ModelSpec::paper_models() {
        let dataset = DatasetSpec::lmsys();
        println!("\n=== {} on {} ({seconds:.0}s trace) ===", model.name, dataset.name);
        let reports = run_paper_set(&model, &dataset, seconds, seed);
        for r in &reports {
            series_summary(&model.name, &r.policy, &r.layer_cdf());
            println!(
                "   cost {:8.1} GB·s | replicas/layer {:5.1} | completed {:4} reqs \
                 | warm {:.3}",
                r.cost_gb_s,
                r.mean_replicas(),
                r.completed_requests,
                r.warm_fraction
            );
        }
        let (meg, orc, eplb, less) = (&reports[0], &reports[1], &reports[2], &reports[3]);
        println!(
            "   moeless: latency -{:.1}% vs megatron, -{:.1}% vs eplb; \
             cost -{:.1}% vs megatron, -{:.1}% vs oracle, -{:.1}% vs eplb",
            reduction_pct(meg.mean_layer_ms(), less.mean_layer_ms()),
            reduction_pct(eplb.mean_layer_ms(), less.mean_layer_ms()),
            reduction_pct(meg.cost_gb_s, less.cost_gb_s),
            reduction_pct(orc.cost_gb_s, less.cost_gb_s),
            reduction_pct(eplb.cost_gb_s, less.cost_gb_s),
        );
    }
}
