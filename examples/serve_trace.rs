//! Request-level serving example (Tier B): the paper's four policies under
//! three distinct arrival scenarios — constant-rate Poisson, bursty
//! (2-state MMPP), and replay of a recorded Azure-style trace — with
//! per-request p50/p95/p99 TTFT and TPOT plus goodput, multi-seed and
//! sharded across the thread pool. A second section prints the classic
//! Fig. 8/10-style layer-latency/cost comparison on the diurnal trace; a
//! third shrinks the KV-cache carve-out on a bursty stream to show the
//! admission controller's queue/preempt/resume feedback on tail TTFT; a
//! fourth replays a long-prompt interference mix monolithically, with
//! stall-free chunked prefill (`--chunk-tokens`, decode packs first and
//! prefill chunks fill the remainder of each iteration), and chunked +
//! disaggregated into prefill/decode pools with a billed KV handoff
//! (`--disagg`, mirroring `moeless replay --chunk-tokens 512 --disagg`).
//!
//! Run: `cargo run --release --example serve_trace [-- --seconds 45 --rps 6 --seeds 2 --chunk-tokens 256]`

use std::time::Instant;

use moeless::baselines::PolicyKind;
use moeless::config::{ClusterSpec, DatasetSpec, DisaggSpec, ModelSpec};
use moeless::metrics::{reduction_pct, SloSpec};
use moeless::sim::sweep::{run_sweep, summarize, SweepSpec};
use moeless::sim::{run, run_paper_set, SimConfig};
use moeless::util::benchkit::series_summary;
use moeless::util::cli::Args;
use moeless::workload::{azure_like_trace, interference_trace, Scenario};

fn main() {
    let args = Args::from_env();
    let seconds = args.f64("seconds", 45.0);
    let rps = args.f64("rps", 6.0);
    let seed = args.u64("seed", 42);
    let n_seeds = args.usize("seeds", 2);
    let model = ModelSpec::by_name(&args.str("model", "mixtral-8x7b")).expect("unknown model");
    let dataset = DatasetSpec::by_name(&args.str("dataset", "lmsys")).expect("unknown dataset");

    // --- request-level SLO sweep: 4 policies x 3 scenarios x N seeds ----
    let mut spec = SweepSpec::new(model.clone(), dataset.clone());
    spec.duration_s = seconds;
    spec.base_rps = rps;
    spec.seeds = (0..n_seeds.max(1) as u64).map(|i| seed + i).collect();
    spec.scenarios = vec![
        Scenario::poisson(),
        Scenario::bursty(),
        // Trace replay: every policy serves the identical recorded stream.
        Scenario::replay(azure_like_trace(&dataset, seconds, rps, seed ^ 0xA2CE)),
    ];

    println!(
        "=== request-level serving: {} on {} — {} policies x {} scenarios x {} seeds \
         on {} threads ===",
        model.name,
        dataset.name,
        spec.policies.len(),
        spec.scenarios.len(),
        spec.seeds.len(),
        spec.threads
    );
    let slo = SloSpec::default();
    let t0 = Instant::now();
    let cells = run_sweep(&spec);
    for row in summarize(&cells, &slo) {
        println!("{}", row.line());
    }
    println!(
        "({} simulations in {:.2}s wall; SLO: ttft<={:.0}ms, tpot<={:.0}ms)",
        cells.len(),
        t0.elapsed().as_secs_f64(),
        slo.ttft_ms,
        slo.tpot_ms
    );

    // --- classic layer-latency / cost comparison (diurnal trace) --------
    println!("\n=== layer-level comparison: {} on {} ({seconds:.0}s diurnal trace) ===", model.name, dataset.name);
    let reports = run_paper_set(&model, &dataset, seconds, seed);
    for r in &reports {
        series_summary(&model.name, &r.policy, r.layer_latency());
        println!(
            "   cost {:8.1} GB·s | replicas/layer {:5.1} | completed {:4} reqs | warm {:.3}",
            r.cost_gb_s,
            r.mean_replicas(),
            r.completed_requests,
            r.warm_fraction
        );
    }
    let (meg, orc, eplb, less) = (&reports[0], &reports[1], &reports[2], &reports[3]);
    println!(
        "   moeless: latency -{:.1}% vs megatron, -{:.1}% vs eplb; \
         cost -{:.1}% vs megatron, -{:.1}% vs oracle, -{:.1}% vs eplb",
        reduction_pct(meg.mean_layer_ms(), less.mean_layer_ms()),
        reduction_pct(eplb.mean_layer_ms(), less.mean_layer_ms()),
        reduction_pct(meg.cost_gb_s, less.cost_gb_s),
        reduction_pct(orc.cost_gb_s, less.cost_gb_s),
        reduction_pct(eplb.cost_gb_s, less.cost_gb_s),
    );

    // --- KV-cache pressure A/B: shrink the KV carve-out on the same ----
    // --- bursty stream and watch admission queue, preempt, and inflate --
    // --- tail TTFT (the memory side of the latency/cost trade-off). ----
    println!("\n=== KV-cache pressure: {} on {} (bursty, {seconds:.0}s) ===", model.name, dataset.name);
    for (label, kv_frac) in [("unconstrained", f64::INFINITY), ("full", 1.0), ("tight", 0.05)] {
        let mut cfg = SimConfig::new(model.clone(), dataset.clone(), PolicyKind::Moeless);
        cfg.scenario = Scenario::bursty();
        cfg.duration_s = seconds;
        cfg.base_rps = rps;
        cfg.seed = seed;
        cfg.kv_frac = kv_frac;
        let r = run(&cfg);
        println!("   {label:<13} {}", r.pressure_line());
        println!(
            "   {label:<13} ttft p99={:.0}ms | completed {} | kv peak util {:.3}",
            r.ttft_cdf().p(99.0),
            r.completed_requests,
            r.peak_kv_util()
        );
    }

    // --- chunked prefill + disaggregation: the long-prompt interference -
    // --- mix, monolithic vs stall-free chunks vs chunks + split pools. --
    let chunk = args.usize("chunk-tokens", 256);
    println!(
        "\n=== chunked prefill + disaggregation: {} on {} (interference mix, chunk={chunk}) ===",
        model.name, dataset.name
    );
    let mix = interference_trace(seconds.min(30.0), 6.0, 32, 16, 10.0, 6000, 8);
    for (label, chunk_tokens, disagg) in
        [("monolithic", 0usize, false), ("chunked", chunk, false), ("chunk+disagg", chunk, true)]
    {
        let mut cfg = SimConfig::new(model.clone(), dataset.clone(), PolicyKind::Moeless);
        cfg.scenario = Scenario::replay(mix.clone());
        cfg.duration_s = 10.0 * seconds; // outlast the arrivals: drain fully
        cfg.seed = seed;
        cfg.prefill_chunk_tokens = chunk_tokens;
        if disagg {
            cfg.disagg = Some(DisaggSpec::even_split(&cfg.cluster));
        }
        let r = run(&cfg);
        println!(
            "   {label:<13} tpot p99={:6.1}ms ttft p99={:6.0}ms goodput={:.2}req/s | {}",
            r.tpot_p99_ms(),
            r.ttft_cdf().p(99.0),
            r.goodput_rps(&slo),
            r.phase_line()
        );
    }

    // --- heterogeneous fleet A/B: the same bursty stream on the uniform -
    // --- testbed vs a mixed 2xH100 + 6xA6000 fleet, capacity-aware vs ---
    // --- token-balanced decisions (evaluation always on real speeds). ---
    println!(
        "\n=== heterogeneous fleet: {} on {} (bursty, {seconds:.0}s) ===",
        model.name, dataset.name
    );
    for (label, cluster, aware) in [
        ("uniform-a6000x8", ClusterSpec::a6000_x8(), true),
        ("hetero-aware", ClusterSpec::hetero_h100_a6000(), true),
        ("hetero-balanced", ClusterSpec::hetero_h100_a6000(), false),
    ] {
        let mut cfg = SimConfig::new(model.clone(), dataset.clone(), PolicyKind::Moeless);
        cfg.scenario = Scenario::bursty();
        cfg.duration_s = seconds;
        cfg.base_rps = rps;
        cfg.seed = seed;
        cfg.cluster = cluster;
        cfg.cluster.capacity_aware = aware;
        let r = run(&cfg);
        println!(
            "   {label:<16} mean_layer={:6.3}ms p99={:6.3}ms ttft p99={:5.0}ms dollar=${:.4}",
            r.mean_layer_ms(),
            r.layer_forward.p(99.0),
            r.ttft_cdf().p(99.0),
            r.dollar_cost
        );
        println!("   {label:<16} {}", r.gpu_line());
    }
}
