//! Quickstart: the end-to-end Tier-A driver (DESIGN.md deliverable (b)/(e2e)).
//!
//! Loads the real TinyMoE AOT artifacts (built once by `make artifacts`),
//! serves a batch of requests through the **decomposed serverless path**
//! (attention → Pallas gate → per-expert serverless function invocations
//! scaled by Algorithm 1 and placed by Algorithm 2), validates the logits
//! bit-for-bit-ish against the monolithic compiled model, and reports
//! throughput + serverless statistics.
//!
//! Run: `cargo run --release --example quickstart`

use std::time::Instant;

use moeless::config::MoelessParams;
use moeless::model::{length_mask, monolithic_logits, open_default, DecomposedServer};
use moeless::util::rng::Pcg;

fn main() {
    let Some(mut srv) = DecomposedServer::open_default(MoelessParams::default()) else {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    };
    let d = srv.dims;
    println!(
        "TinyMoE over PJRT: {} layers x {} experts (top-{}), batch {}x{} tokens, \
         expert capacity {}",
        d.n_layers, d.n_experts, d.top_k, d.batch, d.seq, d.capacity
    );

    // 1. Validate: decomposed serverless execution == monolithic artifact.
    let mut rng = Pcg::seeded(7);
    let tokens: Vec<i32> = (0..d.n_tokens()).map(|_| rng.below(d.vocab) as i32).collect();
    let lens: Vec<usize> = (0..d.batch).map(|_| rng.range(d.seq / 2, d.seq + 1)).collect();
    let (deco, stats) = srv.forward(&tokens, &lens).expect("decomposed forward");
    let (mut store, rt) = open_default().unwrap();
    let mono = monolithic_logits(&rt, &mut store, &tokens, &length_mask(&lens, d.batch, d.seq))
        .expect("monolithic forward");
    let diff = deco.max_abs_diff(&mono);
    println!(
        "validation: decomposed vs monolithic max |Δlogit| = {diff:.2e} \
         ({} expert invocations, {} cold / {} warm starts)",
        stats.expert_invocations, stats.cold_starts, stats.warm_starts
    );
    assert!(diff < 1e-3, "decomposition must be numerically faithful");

    // 2. Serve: auto-regressive generation with serverless experts.
    let prompts: Vec<Vec<i32>> = (0..d.batch)
        .map(|_| (0..rng.range(4, d.seq / 2)).map(|_| rng.below(d.vocab) as i32).collect())
        .collect();
    let n_new = 8;
    let t0 = Instant::now();
    let (seqs, gstats) = srv.generate(&prompts, n_new).expect("generation");
    let secs = t0.elapsed().as_secs_f64();
    let produced = seqs.len() * n_new;
    println!(
        "served {} requests, {} new tokens in {:.2}s -> {:.1} tok/s \
         | pred accuracy {:.3} | mispredictions {} | warm fraction {:.3}",
        seqs.len(),
        produced,
        secs,
        produced as f64 / secs,
        gstats.pred_accuracy,
        gstats.mispredictions,
        srv.manager.warm_fraction()
    );
    println!("quickstart OK");
}
