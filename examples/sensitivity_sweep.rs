//! Sensitivity sweep example: MoEless's two operating knobs — prediction
//! distance d and CV threshold V — swept on one model/dataset, printing the
//! Fig. 13/15 trade-off curves (Tier B).
//!
//! Run: `cargo run --release --example sensitivity_sweep [-- --model phi-3.5-moe]`

use moeless::baselines::PolicyKind;
use moeless::config::{DatasetSpec, ModelSpec};
use moeless::sim::{run, SimConfig};
use moeless::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let model = ModelSpec::by_name(&args.str("model", "mixtral-8x7b")).expect("unknown model");
    let dataset = DatasetSpec::by_name(&args.str("dataset", "lmsys")).expect("unknown dataset");
    let seconds = args.f64("seconds", 60.0);

    let base = |d: usize, v: f64| {
        let mut cfg = SimConfig::new(model.clone(), dataset.clone(), PolicyKind::Moeless);
        cfg.duration_s = seconds;
        cfg.params.prediction_distance = d;
        cfg.params.cv_threshold = v;
        run(&cfg)
    };

    println!("=== prediction distance sweep ({} on {}) ===", model.name, dataset.name);
    println!("{:>3} {:>12} {:>14} {:>10} {:>8}", "d", "fwd (ms)", "replicas/layer", "accuracy", "cold");
    for d in 1..=5 {
        let r = base(d, 0.2);
        println!(
            "{d:>3} {:>12.3} {:>14.2} {:>10.3} {:>8}",
            r.mean_layer_ms(),
            r.mean_replicas(),
            r.mean_pred_accuracy(),
            r.cold_starts
        );
    }

    println!("\n=== CV threshold sweep ===");
    println!("{:>4} {:>12} {:>14}", "V", "fwd (ms)", "replicas/layer");
    for v10 in [2, 4, 6, 8, 10] {
        let r = base(1, v10 as f64 / 10.0);
        println!("{:>4.1} {:>12.3} {:>14.2}", v10 as f64 / 10.0, r.mean_layer_ms(), r.mean_replicas());
    }
    println!("\noperating point: d=1, V=0.2 (the paper's §6.4 choice)");
}
