//! A small comment/string-aware Rust tokenizer.
//!
//! The lint rules (see [`crate::rules`]) are token-shaped — named method
//! calls, comparison operators, macro invocations — so a full `syn` parse
//! is unnecessary (and unavailable: the build is offline, no crates.io).
//! The lexer's one job is to never misread a comment, string literal, or
//! char literal as code, and to distinguish float literals from integers
//! and from tuple-field accesses (`x.0`).

/// Token classes the rules discriminate on.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    Ident,
    Int,
    Float,
    Punct,
    Str,
    Char,
    Lifetime,
}

/// One lexed token with its 1-based source line.
#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn is_ident(&self, text: &str) -> bool {
        self.kind == TokKind::Ident && self.text == text
    }
}

/// One comment (line or block) with the 1-based line it starts on.
/// Comments carry the lint directives (`pallas-lint: allow(...)` /
/// `pallas-lint: treat-as(...)`), so they are collected, not discarded.
#[derive(Clone, Debug)]
pub struct Comment {
    pub line: u32,
    pub text: String,
}

/// Tokenize `src`, returning (code tokens, comments).
pub fn lex(src: &str) -> (Vec<Tok>, Vec<Comment>) {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: Vec<Comment> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let text_of = |a: usize, b: usize| -> String { cs[a..b].iter().collect() };

    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // Line comment (also covers `///` and `//!` doc comments).
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            comments.push(Comment { line, text: text_of(start, i) });
            continue;
        }
        // Block comment, nesting like Rust's.
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text: text_of(start, i) });
            continue;
        }
        // Raw strings: r"...", r#"..."#, br"...", br#"..."#.
        if c == 'r' || c == 'b' {
            if let Some((body_start, hashes)) = raw_str_hashes(&cs, i) {
                let start = i;
                let start_line = line;
                i = body_start; // first char after the opening quote
                while i < n {
                    if cs[i] == '\n' {
                        line += 1;
                        i += 1;
                        continue;
                    }
                    if cs[i] == '"' && closes_raw(&cs, i, hashes) {
                        i += 1 + hashes;
                        break;
                    }
                    i += 1;
                }
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: text_of(start, i.min(n)),
                    line: start_line,
                });
                continue;
            }
        }
        // Cooked strings: "..." and b"...".
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            let start = i;
            let start_line = line;
            i += if c == 'b' { 2 } else { 1 };
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                    continue;
                }
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                    continue;
                }
                if cs[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Str, text: text_of(start, i.min(n)), line: start_line });
            continue;
        }
        // Byte char literal: b'x'.
        if c == 'b' && i + 1 < n && cs[i + 1] == '\'' {
            if let Some(end) = char_literal_end(&cs, i + 1) {
                toks.push(Tok { kind: TokKind::Char, text: text_of(i, end), line });
                i = end;
                continue;
            }
        }
        // Lifetime vs char literal.
        if c == '\'' {
            if let Some(end) = char_literal_end(&cs, i) {
                toks.push(Tok { kind: TokKind::Char, text: text_of(i, end), line });
                i = end;
                continue;
            }
            // `'ident` lifetime (or loop label).
            let mut j = i + 1;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            if j > i + 1 {
                toks.push(Tok { kind: TokKind::Lifetime, text: text_of(i, j), line });
                i = j;
                continue;
            }
            toks.push(Tok { kind: TokKind::Punct, text: "'".into(), line });
            i += 1;
            continue;
        }
        // Numbers (never reached for `x.0`: the `.` lexes as punct first).
        if c.is_ascii_digit() {
            let start = i;
            let mut is_float = false;
            if c == '0' && i + 1 < n && matches!(cs[i + 1], 'x' | 'X' | 'o' | 'b') {
                i += 2;
                while i < n && (cs[i].is_ascii_alphanumeric() || cs[i] == '_') {
                    i += 1;
                }
            } else {
                while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                    i += 1;
                }
                if i < n && cs[i] == '.' {
                    if i + 1 < n && cs[i + 1].is_ascii_digit() {
                        is_float = true;
                        i += 1;
                        while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                            i += 1;
                        }
                    } else if i + 1 >= n
                        || !(cs[i + 1] == '.' || cs[i + 1] == '_' || cs[i + 1].is_alphabetic())
                    {
                        // Trailing-dot float `1.` (but not the range `1..`
                        // or a method call `1.max(..)`).
                        is_float = true;
                        i += 1;
                    }
                }
                if i < n && (cs[i] == 'e' || cs[i] == 'E') {
                    let mut j = i + 1;
                    if j < n && (cs[j] == '+' || cs[j] == '-') {
                        j += 1;
                    }
                    if j < n && cs[j].is_ascii_digit() {
                        is_float = true;
                        i = j;
                        while i < n && (cs[i].is_ascii_digit() || cs[i] == '_') {
                            i += 1;
                        }
                    }
                }
                if i < n && (cs[i].is_alphabetic() || cs[i] == '_') {
                    let sstart = i;
                    while i < n && (cs[i].is_ascii_alphanumeric() || cs[i] == '_') {
                        i += 1;
                    }
                    let suffix = text_of(sstart, i);
                    if suffix == "f32" || suffix == "f64" {
                        is_float = true;
                    }
                }
            }
            let kind = if is_float { TokKind::Float } else { TokKind::Int };
            toks.push(Tok { kind, text: text_of(start, i), line });
            continue;
        }
        // Identifiers and keywords.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (cs[i].is_alphanumeric() || cs[i] == '_') {
                i += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: text_of(start, i), line });
            continue;
        }
        // Multi-char punctuation the rules care about.
        let two: Option<&str> = if i + 1 < n {
            match (c, cs[i + 1]) {
                ('=', '=') => Some("=="),
                ('!', '=') => Some("!="),
                (':', ':') => Some("::"),
                ('-', '>') => Some("->"),
                ('=', '>') => Some("=>"),
                ('.', '.') => Some(".."),
                ('&', '&') => Some("&&"),
                ('|', '|') => Some("||"),
                ('<', '=') => Some("<="),
                ('>', '=') => Some(">="),
                _ => None,
            }
        } else {
            None
        };
        if let Some(p) = two {
            toks.push(Tok { kind: TokKind::Punct, text: p.into(), line });
            i += 2;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    (toks, comments)
}

/// If `i` starts a raw string (`r`/`br` + hashes + `"`), return
/// (index just past the opening quote, hash count).
fn raw_str_hashes(cs: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i + 1; // past 'r'
    if cs[i] == 'b' {
        if i + 1 >= cs.len() || cs[i + 1] != 'r' {
            return None;
        }
        j = i + 2;
    }
    let mut hashes = 0usize;
    while j < cs.len() && cs[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < cs.len() && cs[j] == '"' {
        Some((j + 1, hashes))
    } else {
        None
    }
}

/// Does the `"` at `i` close a raw string with `hashes` trailing hashes?
fn closes_raw(cs: &[char], i: usize, hashes: usize) -> bool {
    if i + hashes >= cs.len() {
        return false;
    }
    (1..=hashes).all(|k| cs[i + k] == '#')
}

/// If `i` is the opening `'` of a char literal, return the index just past
/// the closing quote. Distinguishes `'a'` (char) from `'a` (lifetime) by
/// looking for the close within a short bound.
fn char_literal_end(cs: &[char], i: usize) -> Option<usize> {
    let n = cs.len();
    if i + 1 >= n {
        return None;
    }
    let mut j = i + 1;
    if cs[j] == '\\' {
        j += 2; // escape introducer + kind (covers \n, \', \\, and starts \u)
        if j <= n && cs.get(j - 1) == Some(&'u') {
            // \u{...}
            while j < n && cs[j] != '}' {
                j += 1;
            }
            j += 1;
        }
        if j < n && cs[j] == '\'' {
            return Some(j + 1);
        }
        return None;
    }
    if cs[j] == '\'' {
        return None; // '' is not a char literal
    }
    // Single (possibly multi-byte) char then a closing quote.
    if j + 1 < n && cs[j + 1] == '\'' {
        return Some(j + 2);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).0.into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn floats_vs_ints_vs_tuple_fields() {
        let ks = kinds("let a = x.0 + 1.5 - 2 + 3e4 + 5.;");
        let floats: Vec<&str> = ks
            .iter()
            .filter(|(k, _)| *k == TokKind::Float)
            .map(|(_, t)| t.as_str())
            .collect();
        assert_eq!(floats, vec!["1.5", "3e4", "5."]);
        // `x.0` is ident, punct, int — not a float literal.
        assert!(ks.contains(&(TokKind::Int, "0".into())));
    }

    #[test]
    fn range_is_not_a_float() {
        let ks = kinds("for i in 0..10 {}");
        assert!(ks.contains(&(TokKind::Int, "0".into())));
        assert!(ks.contains(&(TokKind::Punct, "..".into())));
        assert!(!ks.iter().any(|(k, _)| *k == TokKind::Float));
    }

    #[test]
    fn comments_and_strings_hide_code() {
        let (toks, comments) = lex("// panic!(\"no\")\nlet s = \"unwrap()\"; /* x == 0.0 */");
        assert_eq!(comments.len(), 2);
        assert!(!toks.iter().any(|t| t.is_ident("panic")));
        assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
        assert!(!toks.iter().any(|t| t.is("==")));
        assert!(toks.iter().any(|t| t.kind == TokKind::Str));
    }

    #[test]
    fn raw_strings_and_chars_and_lifetimes() {
        let (toks, _) = lex(r####"let r = r#"unwrap() "quoted""#; let c = '='; let b = b'-';"####);
        assert!(!toks.iter().any(|t| t.is_ident("unwrap")));
        assert_eq!(toks.iter().filter(|t| t.kind == TokKind::Char).count(), 2);
        let (toks2, _) = lex("fn f<'a>(x: &'a str) {}");
        assert!(toks2.iter().any(|t| t.kind == TokKind::Lifetime && t.text == "'a"));
    }

    #[test]
    fn line_numbers_advance() {
        let (toks, comments) = lex("a\n\nb // c\nd");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 3);
        assert_eq!(comments[0].line, 3);
        assert_eq!(toks[2].line, 4);
    }
}
