//! `cargo xtask` — repo automation. Today: the pallas-lint pass.
//!
//! ```text
//! cargo xtask lint [paths…]     lint rust/src (default) or the given paths
//! cargo xtask explain <rule>    long-form rationale + fix for one rule
//! ```

use std::path::PathBuf;
use std::process::ExitCode;

use xtask::rules;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(String::as_str) {
        Some("lint") => run_lint(&args[1..]),
        Some("explain") | Some("--explain") => run_explain(&args[1..]),
        Some(other) => {
            eprintln!("xtask: unknown command {other:?}");
            eprintln!("usage: cargo xtask lint [paths…] | cargo xtask explain <rule>");
            ExitCode::from(2)
        }
        None => {
            eprintln!("usage: cargo xtask lint [paths…] | cargo xtask explain <rule>");
            ExitCode::from(2)
        }
    }
}

fn run_lint(args: &[String]) -> ExitCode {
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut it = args.iter();
    while let Some(a) = it.next() {
        if a == "--explain" {
            let Some(rule) = it.next() else {
                eprintln!("xtask: --explain needs a rule id (D1 D2 D3 R1 P1)");
                return ExitCode::from(2);
            };
            return explain(rule);
        }
        paths.push(PathBuf::from(a));
    }
    if paths.is_empty() {
        // Works from the workspace root (CI, `cargo xtask`) and from the
        // xtask directory itself (`cargo test` cwd).
        let default = PathBuf::from("rust/src");
        let fallback = PathBuf::from("../rust/src");
        paths.push(if default.exists() { default } else { fallback });
    }

    let report = match xtask::lint_paths(&paths) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask lint: {e}");
            return ExitCode::from(2);
        }
    };

    for v in &report.violations {
        println!(
            "{}:{}: [{}] {} — hint: {}",
            v.file,
            v.line,
            v.rule,
            v.msg,
            rules::short_hint(&v.rule)
        );
    }
    if !report.allows_used.is_empty() {
        println!("audited exemptions in use ({}):", report.allows_used.len());
        for a in &report.allows_used {
            println!("  {}:{}: allow({}) — {}", a.file, a.line, a.rule, a.msg);
        }
    }
    if report.clean() {
        println!(
            "pallas-lint: {} files clean ({} audited exemptions)",
            report.files_checked,
            report.allows_used.len()
        );
        ExitCode::SUCCESS
    } else {
        println!(
            "pallas-lint: {} violation(s) across {} files — run `cargo xtask explain <rule>`",
            report.violations.len(),
            report.files_checked
        );
        ExitCode::FAILURE
    }
}

fn run_explain(args: &[String]) -> ExitCode {
    let Some(rule) = args.first() else {
        eprintln!("xtask: explain needs a rule id; known rules:");
        for r in rules::RULES {
            eprintln!("  {}  {}", r.id, r.title);
        }
        return ExitCode::from(2);
    };
    explain(rule)
}

fn explain(rule: &str) -> ExitCode {
    let id = rule.to_ascii_uppercase();
    match rules::rule_info(&id) {
        Some(r) => {
            println!("{} — {}", r.id, r.title);
            println!();
            println!("scope:     {}", r.scope);
            println!("rationale: {}", r.rationale);
            println!("fix:       {}", r.fix);
            println!();
            println!(
                "exemption: `// pallas-lint: allow({}) — <reason>` on the offending \
                 line or the line above; every use is reported in the lint output.",
                r.id
            );
            ExitCode::SUCCESS
        }
        None => {
            eprintln!("xtask: unknown rule {rule:?}; known rules:");
            for r in rules::RULES {
                eprintln!("  {}  {}", r.id, r.title);
            }
            ExitCode::from(2)
        }
    }
}
