//! The pallas-lint rule catalog and engine.
//!
//! Five rules over `rust/src`, each protecting an invariant the repo's
//! tests and benchmarks rest on (golden equivalence, multi-seed
//! reproducibility, measured perf trajectories):
//!
//! - **D1** no ordering-dependent iteration over `HashMap`/`HashSet` in
//!   the deterministic sim-core modules (keyed lookup is fine).
//! - **D2** no wall-clock (`Instant::now`/`SystemTime`) or ambient RNG on
//!   the sim path — time comes from the sim clock, randomness from
//!   seeded generators.
//! - **D3** no float `==`/`!=` outside tests — ledger and clock values
//!   accumulate rounding; compare via `util::float`, integer token
//!   counts, or `to_bits()` when bitwise identity is the point.
//! - **R1** no `unwrap()`/`expect()`/`panic!` in library code — return
//!   `anyhow::Result` with context, or route structural invariants
//!   through the audited `util::fail` funnel.
//! - **P1** no `Vec::remove`/`swap_remove`/`insert(0, _)` on the
//!   de-quadraticized batcher/placer hot paths.
//!
//! `// pallas-lint: allow(RULE) — reason` on the offending line (or the
//! line above) grants an audited exemption; every use is reported.

use crate::lexer::{Comment, Tok, TokKind};

/// Explainable metadata for one rule.
pub struct RuleInfo {
    pub id: &'static str,
    pub title: &'static str,
    pub scope: &'static str,
    pub rationale: &'static str,
    pub fix: &'static str,
}

pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "D1",
        title: "no HashMap/HashSet iteration in deterministic sim-core modules",
        scope: "rust/src/{router,sim,placer,scaler,engine,workload,metrics,serverless} \
                (router/reference.rs and the multi-model catalog/loading modules \
                included); keyed lookup/insert/remove is fine",
        rationale: "std hash iteration order is randomized per process; any sim-path \
                    decision derived from it breaks bit-for-bit golden equivalence and \
                    multi-seed reproducibility silently.",
        fix: "use BTreeMap/BTreeSet, or collect keys and sort before iterating (the \
              token scan cannot prove a later sort, so a sorted drain needs an \
              audited `// pallas-lint: allow(D1) — ...`).",
    },
    RuleInfo {
        id: "D2",
        title: "no wall-clock or ambient randomness on the sim path",
        scope: "same sim-core modules as D1",
        rationale: "Instant::now/SystemTime and entropy-seeded RNGs make two runs of \
                    the same (config, seed) diverge; all sim time must derive from the \
                    sim clock and all randomness from explicitly seeded generators.",
        fix: "thread the sim clock value in as an argument; construct RNGs from the \
              run seed (util::rng). Host-perf measurement that only feeds reporting \
              belongs outside the sim path or behind an audited allow.",
    },
    RuleInfo {
        id: "D3",
        title: "no float ==/!= outside tests",
        scope: "all of rust/src except #[cfg(test)] items and debug_assert! bodies",
        rationale: "the clock and KV ledgers accumulate rounding; exact float equality \
                    encodes a fragile assumption that breaks under any re-ordering of \
                    arithmetic (exactly what the perf work keeps doing).",
        fix: "compare with util::float::approx_eq / an explicit epsilon, count in \
              integer tokens, or use to_bits() when bitwise identity is the contract \
              (e.g. uniform-fleet detection).",
    },
    RuleInfo {
        id: "R1",
        title: "no unwrap()/expect()/panic! in library code",
        scope: "all of rust/src except main.rs, #[cfg(test)] items and debug_assert! \
                bodies (assert! with a message is permitted as a contract check)",
        rationale: "library panics turn bad configs and malformed traces into aborts \
                    with no context; the CLI surfaces structured errors instead.",
        fix: "return anyhow::Result with .context(...), or route a structural \
              invariant (\"cannot fail by construction\") through \
              util::fail::{expect_invariant, unrecoverable} — the single audited \
              panic funnel.",
    },
    RuleInfo {
        id: "P1",
        title: "no Vec::remove/swap_remove/insert(0, _) on batcher/placer hot paths",
        scope: "rust/src/router/mod.rs, rust/src/router/arena.rs, rust/src/placer/, \
                rust/src/sim/event.rs, rust/src/sim/multimodel.rs, \
                rust/src/serverless/loading.rs and \
                rust/src/serverless/offload.rs (router/reference.rs and \
                router/pr4.rs are excluded by design: they are the frozen baseline \
                cores that golden equivalence measures against; the frozen lockstep \
                driver in sim/mod.rs is excluded for the same reason)",
        rationale: "PR 4 de-quadraticized these paths with keyed BTreeMap indices; a \
                    positional remove/insert reintroduces O(n) shifts (or an \
                    order-perturbing swap) exactly where the saturated-drain \
                    benchmark measures.",
        fix: "use the keyed indices (BTreeMap remove by key), push/pop at the back, \
              or keep an O(1)-and-order-insensitive swap_remove behind an audited \
              allow stating why ordering cannot matter.",
    },
];

pub fn rule_info(id: &str) -> Option<&'static RuleInfo> {
    RULES.iter().find(|r| r.id == id)
}

/// The one-line fix hint printed next to each violation; the long-form
/// rationale lives in `cargo xtask explain <rule>`.
pub fn short_hint(id: &str) -> &'static str {
    match id {
        "D1" => "use BTreeMap/BTreeSet or sort keys before iterating",
        "D2" => "take the sim clock as an argument; seed RNGs from the run seed",
        "D3" => "use util::float::{approx_eq,is_integer}, integer tokens, or to_bits()",
        "R1" => "return anyhow::Result with context, or go through util::fail",
        "P1" => "remove by key via the BTreeMap index, or push/pop at the back",
        _ => "see `cargo xtask explain <rule>`",
    }
}

/// Which rule families apply to a file.
#[derive(Clone, Copy, Debug, Default)]
pub struct FileClass {
    /// D1/D2 apply: deterministic sim-core module.
    pub sim_core: bool,
    /// P1 applies: de-quadraticized batcher/placer hot path.
    pub hot_path: bool,
    /// R1 applies: library code (everything but the CLI binary).
    pub library: bool,
}

const SIM_CORE_MODULES: &[&str] =
    &["router", "sim", "placer", "scaler", "engine", "workload", "metrics", "serverless"];

/// Classify a file by its repo-relative path, then apply any
/// `pallas-lint: treat-as(...)` directive (used by the test fixtures).
pub fn classify(rel_path: &str, comments: &[Comment]) -> FileClass {
    let rel = rel_path.replace('\\', "/");
    let mut class = FileClass::default();
    if let Some(idx) = rel.find("rust/src/") {
        let tail = &rel[idx + "rust/src/".len()..];
        let top = tail.split('/').next().unwrap_or("").trim_end_matches(".rs");
        class.sim_core = SIM_CORE_MODULES.contains(&top);
        class.hot_path = tail == "router/mod.rs"
            || tail == "router/arena.rs"
            || tail.starts_with("placer/")
            || tail == "sim/event.rs"
            || tail == "sim/multimodel.rs"
            || tail == "serverless/loading.rs"
            || tail == "serverless/offload.rs";
        class.library = tail != "main.rs";
        if tail == "router/reference.rs" {
            // Frozen pre-PR4 core: held to the determinism rules (golden
            // equivalence depends on it), but not to the hot-path rule it
            // exists to be measured against.
            class.hot_path = false;
        }
    } else {
        class.library = true;
    }
    for c in comments {
        if let Some(rest) = c.text.split("pallas-lint:").nth(1) {
            if let Some(kinds) = parse_paren(rest, "treat-as") {
                class = FileClass::default();
                for kind in kinds.split(',') {
                    match kind.trim() {
                        "sim-core" => class.sim_core = true,
                        "hot-path" => class.hot_path = true,
                        "library" => class.library = true,
                        _ => {}
                    }
                }
                // sim-core and hot-path files are always library code too.
                class.library |= class.sim_core || class.hot_path;
                break;
            }
        }
    }
    class
}

/// `rest` starts just past "pallas-lint:"; if it continues
/// `<key>(<inner>)`, return `inner`.
fn parse_paren(rest: &str, key: &str) -> Option<String> {
    let t = rest.trim_start();
    let t = t.strip_prefix(key)?;
    let t = t.trim_start().strip_prefix('(')?;
    let close = t.find(')')?;
    Some(t[..close].to_string())
}

/// One diagnostic.
#[derive(Clone, Debug)]
pub struct Violation {
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// One `allow` comment that suppressed a violation (the audit trail).
#[derive(Clone, Debug)]
pub struct AllowUse {
    pub line: u32,
    pub rule: String,
    pub reason: String,
}

/// Everything the engine found in one file.
#[derive(Clone, Debug, Default)]
pub struct FileReport {
    pub violations: Vec<Violation>,
    pub allows_used: Vec<AllowUse>,
}

struct AllowComment {
    line: u32,
    rule: String,
    reason: String,
    used: bool,
}

/// Parse every `pallas-lint: allow(RULE) — reason` comment.
fn collect_allows(comments: &[Comment]) -> (Vec<AllowComment>, Vec<Violation>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for c in comments {
        let Some(rest) = c.text.split("pallas-lint:").nth(1) else { continue };
        let Some(rule) = parse_paren(rest, "allow") else { continue };
        let rule = rule.trim().to_string();
        // The reason is whatever follows the closing paren, minus
        // separator dashes/spaces.
        let after = rest
            .split_once(')')
            .map(|(_, r)| r)
            .unwrap_or("")
            .trim_matches(|ch: char| ch.is_whitespace() || ch == '-' || ch == '—' || ch == '–')
            .to_string();
        if rule_info(&rule).is_none() {
            bad.push(Violation {
                line: c.line,
                rule: "allow",
                msg: format!("allow names unknown rule {rule:?} (known: D1 D2 D3 R1 P1)"),
            });
            continue;
        }
        if after.len() < 5 {
            bad.push(Violation {
                line: c.line,
                rule: "allow",
                msg: format!("allow({rule}) must carry a written reason after the dash"),
            });
            continue;
        }
        allows.push(AllowComment { line: c.line, rule, reason: after, used: false });
    }
    (allows, bad)
}

/// Token-index spans exempt from all rules: `#[cfg(test)]` items and
/// `debug_assert*!` argument lists.
fn exempt_spans(toks: &[Tok]) -> Vec<bool> {
    let mut skip = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        // #[cfg(test)] <attrs>* <item>
        if toks[i].is("#") && i + 1 < toks.len() && toks[i + 1].is("[") {
            let (inner, after_attr) = bracketed(toks, i + 1);
            if inner_starts_cfg_test(&inner) {
                let mut k = after_attr;
                // Skip any further attributes on the same item.
                while k + 1 < toks.len() && toks[k].is("#") && toks[k + 1].is("[") {
                    let (_, nk) = bracketed(toks, k + 1);
                    k = nk;
                }
                // Skip the item: to `;` at depth 0 before any brace, or to
                // the end of its balanced `{ ... }` block.
                let mut depth = 0i32;
                while k < toks.len() {
                    let t = &toks[k].text;
                    if t == "{" {
                        depth += 1;
                    } else if t == "}" {
                        depth -= 1;
                        if depth == 0 {
                            k += 1;
                            break;
                        }
                    } else if t == ";" && depth == 0 {
                        k += 1;
                        break;
                    }
                    k += 1;
                }
                for s in skip.iter_mut().take(k).skip(i) {
                    *s = true;
                }
                i = k;
                continue;
            }
        }
        // debug_assert! / debug_assert_eq! / debug_assert_ne!
        if toks[i].kind == TokKind::Ident
            && toks[i].text.starts_with("debug_assert")
            && i + 1 < toks.len()
            && toks[i + 1].is("!")
        {
            let mut k = i + 2;
            if k < toks.len() && (toks[k].is("(") || toks[k].is("[") || toks[k].is("{")) {
                let open = toks[k].text.clone();
                let close = match open.as_str() {
                    "(" => ")",
                    "[" => "]",
                    _ => "}",
                };
                let mut depth = 0i32;
                while k < toks.len() {
                    if toks[k].text == open {
                        depth += 1;
                    } else if toks[k].text == close {
                        depth -= 1;
                        if depth == 0 {
                            k += 1;
                            break;
                        }
                    }
                    k += 1;
                }
            }
            for s in skip.iter_mut().take(k).skip(i) {
                *s = true;
            }
            i = k;
            continue;
        }
        i += 1;
    }
    skip
}

/// Collect the tokens inside `[...]` starting at the `[` at `open_idx`;
/// returns (inner token texts, index just past the closing `]`).
fn bracketed(toks: &[Tok], open_idx: usize) -> (Vec<String>, usize) {
    let mut inner = Vec::new();
    let mut depth = 0i32;
    let mut k = open_idx;
    while k < toks.len() {
        if toks[k].is("[") {
            depth += 1;
            if depth == 1 {
                k += 1;
                continue;
            }
        } else if toks[k].is("]") {
            depth -= 1;
            if depth == 0 {
                return (inner, k + 1);
            }
        }
        inner.push(toks[k].text.clone());
        k += 1;
    }
    (inner, k)
}

fn inner_starts_cfg_test(inner: &[String]) -> bool {
    inner.len() >= 4 && inner[0] == "cfg" && inner[1] == "(" && inner[2] == "test" && inner[3] == ")"
}

/// Names bound (let/field/param) to a type in `type_names`, plus names
/// assigned `Type::new()` / `Type::with_capacity(..)` / `Type::from(..)`
/// / `Type::default()`, plus (for `vec_macro`) `= vec![...]`.
fn collect_typed_names(toks: &[Tok], type_names: &[&str], vec_macro: bool) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let mut push = |n: &str| {
        if !names.iter().any(|x| x == n) {
            names.push(n.to_string());
        }
    };
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Ident && type_names.contains(&t.text.as_str()) {
            // `name : [&][&][mut] [[]] Type` — refs, and slices/arrays of
            // the type (`&mut [Vec<usize>]`), still bind containers whose
            // elements the rules care about.
            let mut k = i;
            while k > 0
                && (toks[k - 1].is("&")
                    || toks[k - 1].is("&&")
                    || toks[k - 1].is("[")
                    || toks[k - 1].is_ident("mut"))
            {
                k -= 1;
            }
            if k >= 2 && toks[k - 1].is(":") && toks[k - 2].kind == TokKind::Ident {
                push(&toks[k - 2].text);
            }
            // `let [mut] name = Type::new/with_capacity/from/default`
            if i + 2 < toks.len()
                && toks[i + 1].is("::")
                && matches!(toks[i + 2].text.as_str(), "new" | "with_capacity" | "from" | "default")
                && i >= 2
                && toks[i - 1].is("=")
                && toks[i - 2].kind == TokKind::Ident
            {
                push(&toks[i - 2].text);
            }
        }
        if vec_macro
            && t.is_ident("vec")
            && i + 1 < toks.len()
            && toks[i + 1].is("!")
            && i >= 2
            && toks[i - 1].is("=")
            && toks[i - 2].kind == TokKind::Ident
        {
            push(&toks[i - 2].text);
        }
    }
    names
}

/// Names annotated `: f32` / `: f64` anywhere in the file (fields, params,
/// lets). Used by D3 to recognize float operands beyond literals.
fn collect_float_names(toks: &[Tok]) -> Vec<String> {
    collect_typed_names(toks, &["f32", "f64"], false)
}

/// The receiver identifier of a `.method(` call whose method ident is at
/// `mi`: `name.m(...)`, `self.name.m(...)`, or `name[idx].m(...)`.
fn receiver_name(toks: &[Tok], mi: usize) -> Option<String> {
    if mi < 2 || !toks[mi - 1].is(".") {
        return None;
    }
    let r = mi - 2;
    if toks[r].kind == TokKind::Ident {
        return Some(toks[r].text.clone());
    }
    if toks[r].is("]") {
        // scan back to the matching `[`, then take the ident before it
        let mut depth = 0i32;
        let mut k = r;
        loop {
            if toks[k].is("]") {
                depth += 1;
            } else if toks[k].is("[") {
                depth -= 1;
                if depth == 0 {
                    if k >= 1 && toks[k - 1].kind == TokKind::Ident {
                        return Some(toks[k - 1].text.clone());
                    }
                    return None;
                }
            }
            if k == 0 {
                return None;
            }
            k -= 1;
        }
    }
    None
}

const HASH_ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];

/// Lint one lexed file. `rel_path` is used only for classification.
pub fn lint_file(rel_path: &str, toks: &[Tok], comments: &[Comment]) -> FileReport {
    let class = classify(rel_path, comments);
    let skip = exempt_spans(toks);
    let (mut allows, mut bad_allows) = collect_allows(comments);
    let float_names = collect_float_names(toks);
    let hash_names = collect_typed_names(toks, &["HashMap", "HashSet"], false);
    let vec_names = collect_typed_names(toks, &["Vec", "VecDeque"], true);

    let mut raw: Vec<Violation> = Vec::new();
    let n = toks.len();
    for i in 0..n {
        if skip[i] {
            continue;
        }
        let t = &toks[i];
        let next_is = |k: usize, s: &str| i + k < n && toks[i + k].is(s);

        // ---- D1: hash iteration in sim-core ------------------------------
        if class.sim_core && t.kind == TokKind::Ident {
            if HASH_ITER_METHODS.contains(&t.text.as_str()) && next_is(1, "(") {
                if let Some(recv) = receiver_name(toks, i) {
                    if hash_names.iter().any(|h| *h == recv) {
                        raw.push(Violation {
                            line: t.line,
                            rule: "D1",
                            msg: format!(
                                "ordering-dependent `.{}()` on hash collection `{recv}`",
                                t.text
                            ),
                        });
                    }
                }
            }
            if t.text == "for" {
                // `for <pat> in <expr> {` — flag a hash-typed name in expr.
                let mut j = i + 1;
                let mut depth = 0i32;
                while j < n && !(depth == 0 && toks[j].is_ident("in")) {
                    match toks[j].text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "{" => break,
                        _ => {}
                    }
                    j += 1;
                }
                if j < n && toks[j].is_ident("in") {
                    let mut k = j + 1;
                    let mut d = 0i32;
                    while k < n && !(d == 0 && toks[k].is("{")) {
                        match toks[k].text.as_str() {
                            "(" | "[" => d += 1,
                            ")" | "]" => d -= 1,
                            _ => {}
                        }
                        if toks[k].kind == TokKind::Ident
                            && hash_names.iter().any(|h| *h == toks[k].text)
                        {
                            raw.push(Violation {
                                line: toks[k].line,
                                rule: "D1",
                                msg: format!(
                                    "`for` loop over hash collection `{}`",
                                    toks[k].text
                                ),
                            });
                            break;
                        }
                        k += 1;
                    }
                }
            }
        }

        // ---- D2: wall-clock / ambient RNG in sim-core --------------------
        if class.sim_core && t.kind == TokKind::Ident {
            if t.text == "Instant" && next_is(1, "::") && i + 2 < n && toks[i + 2].is_ident("now") {
                raw.push(Violation {
                    line: t.line,
                    rule: "D2",
                    msg: "`Instant::now()` on the sim path".into(),
                });
            } else if t.text == "SystemTime" {
                raw.push(Violation {
                    line: t.line,
                    rule: "D2",
                    msg: "`SystemTime` on the sim path".into(),
                });
            } else if t.text == "thread_rng" || t.text == "from_entropy" {
                raw.push(Violation {
                    line: t.line,
                    rule: "D2",
                    msg: format!("ambient RNG (`{}`) on the sim path", t.text),
                });
            }
        }

        // ---- D3: float ==/!= ---------------------------------------------
        if t.kind == TokKind::Punct && (t.text == "==" || t.text == "!=") {
            let lhs = i.checked_sub(1).map(|k| &toks[k]);
            let lhs_float = lhs.map(|l| {
                l.kind == TokKind::Float
                    || (l.kind == TokKind::Ident && float_names.iter().any(|f| *f == l.text))
            });
            // rhs: skip unary minus and opening parens
            let mut j = i + 1;
            while j < n && (toks[j].is("-") || toks[j].is("(")) {
                j += 1;
            }
            let rhs = toks.get(j);
            let rhs_float = rhs.map(|r| {
                r.kind == TokKind::Float
                    || (r.kind == TokKind::Ident
                        && float_names.iter().any(|f| *f == r.text)
                        && !(j + 1 < n
                            && (toks[j + 1].is(".") || toks[j + 1].is("::") || toks[j + 1].is("("))))
            });
            // A str/char/int literal on either side proves the comparison
            // is not a float one (Rust would reject the mixed types) —
            // except an Int right after `.`, which is a tuple index.
            let non_float = |tok: Option<&Tok>, prev_dot: bool| {
                tok.is_some_and(|x| {
                    matches!(x.kind, TokKind::Str | TokKind::Char)
                        || (x.kind == TokKind::Int && !prev_dot)
                })
            };
            let lhs_nf = non_float(lhs, i >= 2 && toks[i - 2].is("."));
            let rhs_nf = non_float(rhs, false);
            if (lhs_float.unwrap_or(false) || rhs_float.unwrap_or(false)) && !lhs_nf && !rhs_nf {
                raw.push(Violation {
                    line: t.line,
                    rule: "D3",
                    msg: format!("float `{}` comparison", t.text),
                });
            }
        }

        // ---- R1: unwrap/expect/panic! in library code --------------------
        if class.library && t.kind == TokKind::Ident {
            if (t.text == "unwrap" || t.text == "expect")
                && i >= 1
                && toks[i - 1].is(".")
                && next_is(1, "(")
            {
                raw.push(Violation {
                    line: t.line,
                    rule: "R1",
                    msg: format!("`.{}()` in library code", t.text),
                });
            } else if t.text == "panic" && next_is(1, "!") {
                raw.push(Violation {
                    line: t.line,
                    rule: "R1",
                    msg: "`panic!` in library code".into(),
                });
            }
        }

        // ---- P1: positional Vec ops on hot paths -------------------------
        if class.hot_path && t.kind == TokKind::Ident && next_is(1, "(") {
            let vec_recv = receiver_name(toks, i)
                .map(|r| vec_names.iter().any(|v| *v == r))
                .unwrap_or(false);
            if vec_recv {
                if t.text == "swap_remove" {
                    raw.push(Violation {
                        line: t.line,
                        rule: "P1",
                        msg: "order-perturbing `swap_remove` on a hot-path Vec".into(),
                    });
                } else if t.text == "remove" {
                    raw.push(Violation {
                        line: t.line,
                        rule: "P1",
                        msg: "O(n) positional `remove` on a hot-path Vec".into(),
                    });
                } else if t.text == "insert"
                    && i + 2 < n
                    && toks[i + 2].kind == TokKind::Int
                    && toks[i + 2].text == "0"
                    && i + 3 < n
                    && toks[i + 3].is(",")
                {
                    raw.push(Violation {
                        line: t.line,
                        rule: "P1",
                        msg: "O(n) `insert(0, _)` on a hot-path Vec".into(),
                    });
                }
            }
        }
    }

    // Apply allows: an allow on the violation's own line or the line above
    // suppresses exactly its named rule.
    let mut report = FileReport::default();
    for v in raw {
        let hit = allows
            .iter_mut()
            .find(|a| a.rule == v.rule && (a.line == v.line || a.line + 1 == v.line));
        match hit {
            Some(a) => {
                if !a.used {
                    report.allows_used.push(AllowUse {
                        line: a.line,
                        rule: a.rule.clone(),
                        reason: a.reason.clone(),
                    });
                }
                a.used = true;
            }
            None => report.violations.push(v),
        }
    }
    // An allow that suppressed nothing is itself a defect: it either
    // drifted off its line or papers over nothing.
    for a in &allows {
        if !a.used {
            bad_allows.push(Violation {
                line: a.line,
                rule: "allow",
                msg: format!(
                    "unused allow({}) — no {} violation on this or the next line",
                    a.rule, a.rule
                ),
            });
        }
    }
    report.violations.extend(bad_allows);
    report.violations.sort_by_key(|v| v.line);
    // One diagnostic per (line, rule): a `for x in map.iter()` trips both
    // the method check and the loop check, which is the same defect.
    report.violations.dedup_by(|a, b| a.line == b.line && a.rule == b.rule);
    report
}
