//! pallas-lint: the repo's determinism & robustness static-analysis pass.
//!
//! See `rules::RULES` for the catalog, or run `cargo xtask explain <rule>`.
//! The library half exists so the fixture tests (and the `repo_is_clean`
//! test that tier-1 runs) can drive the engine directly.

pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

/// One diagnostic attributed to a file, ready to print.
#[derive(Clone, Debug)]
pub struct Finding {
    pub file: String,
    pub line: u32,
    pub rule: String,
    pub msg: String,
}

/// The outcome of linting a set of paths.
#[derive(Clone, Debug, Default)]
pub struct LintReport {
    pub files_checked: usize,
    pub violations: Vec<Finding>,
    pub allows_used: Vec<Finding>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Recursively collect `.rs` files under `root`, sorted for deterministic
/// output. A file path is returned as-is.
fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(());
    }
    let entries =
        fs::read_dir(root).map_err(|e| format!("cannot read directory {}: {e}", root.display()))?;
    let mut children: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("cannot read entry under {}: {e}", root.display()))?;
        children.push(entry.path());
    }
    children.sort();
    for child in children {
        if child.is_dir() {
            collect_rs_files(&child, out)?;
        } else if child.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(child);
        }
    }
    Ok(())
}

/// Normalize a path for classification and display: forward slashes,
/// stripped of any leading `./`.
fn display_path(p: &Path) -> String {
    let s = p.to_string_lossy().replace('\\', "/");
    s.strip_prefix("./").unwrap_or(&s).to_string()
}

/// Lint every `.rs` file under the given paths (files or directories).
pub fn lint_paths(paths: &[PathBuf]) -> Result<LintReport, String> {
    let mut files = Vec::new();
    for p in paths {
        if !p.exists() {
            return Err(format!("path does not exist: {}", p.display()));
        }
        collect_rs_files(p, &mut files)?;
    }
    files.sort();
    files.dedup();

    let mut report = LintReport::default();
    for f in &files {
        let src = fs::read_to_string(f).map_err(|e| format!("cannot read {}: {e}", f.display()))?;
        let rel = display_path(f);
        let (toks, comments) = lexer::lex(&src);
        let file_report = rules::lint_file(&rel, &toks, &comments);
        report.files_checked += 1;
        for v in file_report.violations {
            report.violations.push(Finding {
                file: rel.clone(),
                line: v.line,
                rule: v.rule.to_string(),
                msg: v.msg,
            });
        }
        for a in file_report.allows_used {
            report.allows_used.push(Finding {
                file: rel.clone(),
                line: a.line,
                rule: a.rule,
                msg: a.reason,
            });
        }
    }
    Ok(report)
}
