//! Fixture tests for the pallas-lint engine: one positive and one negative
//! case per rule, allow-comment scoping, and — because tier-1 runs this
//! crate's tests — a check that `rust/src` itself is clean.

use std::path::PathBuf;

use xtask::LintReport;

fn fixture(name: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn lint_fixture(name: &str) -> LintReport {
    xtask::lint_paths(&[fixture(name)]).expect("fixture should lint")
}

fn rule_ids(report: &LintReport) -> Vec<&str> {
    report.violations.iter().map(|v| v.rule.as_str()).collect()
}

#[test]
fn d1_flags_hash_iteration() {
    let report = lint_fixture("d1_violation.rs");
    let ids = rule_ids(&report);
    assert!(ids.len() >= 2, "expected both hash loops flagged: {:?}", report.violations);
    assert!(ids.iter().all(|r| *r == "D1"), "only D1 expected: {:?}", report.violations);
}

#[test]
fn d1_permits_keyed_lookup_and_btree_iteration() {
    let report = lint_fixture("d1_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn d2_flags_wall_clock() {
    let report = lint_fixture("d2_violation.rs");
    assert_eq!(rule_ids(&report), vec!["D2"], "{:?}", report.violations);
}

#[test]
fn d2_permits_sim_clock_arguments() {
    let report = lint_fixture("d2_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn d3_flags_float_equality() {
    let report = lint_fixture("d3_violation.rs");
    assert_eq!(rule_ids(&report), vec!["D3", "D3"], "{:?}", report.violations);
}

#[test]
fn d3_permits_epsilon_integer_and_debug_assert() {
    let report = lint_fixture("d3_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn r1_flags_unwrap_expect_panic() {
    let report = lint_fixture("r1_violation.rs");
    assert_eq!(rule_ids(&report), vec!["R1", "R1", "R1"], "{:?}", report.violations);
}

#[test]
fn r1_permits_fallible_apis_and_test_modules() {
    let report = lint_fixture("r1_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn p1_flags_positional_vec_surgery() {
    let report = lint_fixture("p1_violation.rs");
    assert_eq!(rule_ids(&report), vec!["P1", "P1", "P1"], "{:?}", report.violations);
}

#[test]
fn p1_permits_keyed_indices_and_back_ops() {
    let report = lint_fixture("p1_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn p1_flags_positional_event_queue_surgery() {
    let report = lint_fixture("p1_event_heap_violation.rs");
    assert_eq!(rule_ids(&report), vec!["P1", "P1"], "{:?}", report.violations);
}

#[test]
fn p1_permits_binary_heap_event_scheduling() {
    let report = lint_fixture("p1_event_heap_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn p1_scope_covers_the_event_driver_module() {
    // The event driver is hot-path-classified by path (no directive in
    // the real file), and the frozen lockstep baseline in sim/mod.rs is
    // deliberately not.
    let event = xtask::rules::classify("rust/src/sim/event.rs", &[]);
    assert!(event.hot_path, "sim/event.rs must be under P1");
    assert!(event.sim_core, "sim/event.rs must be under D1/D2");
    let lockstep = xtask::rules::classify("rust/src/sim/mod.rs", &[]);
    assert!(!lockstep.hot_path, "the frozen lockstep driver is the baseline, not a hot path");
    // Linting the real file directly must come back clean — the heap
    // discipline is enforced, not aspirational.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src/sim/event.rs");
    let report = xtask::lint_paths(&[path]).expect("event driver should lint");
    assert!(report.clean(), "sim/event.rs must stay lint-clean: {:?}", report.violations);
}

#[test]
fn multimodel_scope_flags_the_loading_antipatterns() {
    // The PR-8 scope extension: hash-order eviction (D1), wall-clock
    // recency stamps (D2) and positional queue surgery (P1) in one
    // warm-ledger fixture shaped like the colocation modules.
    let report = lint_fixture("multimodel_loading_violation.rs");
    let ids = rule_ids(&report);
    assert!(ids.contains(&"D1"), "hash-order eviction must flag D1: {:?}", report.violations);
    assert!(ids.contains(&"D2"), "wall-clock stamp must flag D2: {:?}", report.violations);
    assert!(ids.contains(&"P1"), "positional retire must flag P1: {:?}", report.violations);
}

#[test]
fn multimodel_scope_permits_the_keyed_ledger_shape() {
    // The shape serverless/loading.rs and sim/multimodel.rs actually use:
    // BTreeMap LRU keyed by (stamp, model), Option::take for in-flight
    // slots — clean under the same directives.
    let report = lint_fixture("multimodel_loading_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn lint_scope_covers_the_multimodel_modules() {
    // Path classification, no directives: the colocation sim and the
    // checkpoint-loading ledger are hot-path + sim-core; the catalog is
    // sim-core (workload); serverless is a sim-core module now.
    for path in ["rust/src/sim/multimodel.rs", "rust/src/serverless/loading.rs"] {
        let class = xtask::rules::classify(path, &[]);
        assert!(class.hot_path, "{path} must be under P1");
        assert!(class.sim_core, "{path} must be under D1/D2");
    }
    let catalog = xtask::rules::classify("rust/src/workload/catalog.rs", &[]);
    assert!(catalog.sim_core, "the catalog trace generator must be under D1/D2");
    assert!(!catalog.hot_path, "the catalog is generation-time, not a hot path");
    let manager = xtask::rules::classify("rust/src/serverless/mod.rs", &[]);
    assert!(manager.sim_core, "serverless/ joined the sim-core scope");
    assert!(!manager.hot_path, "only loading.rs and offload.rs carry the hot-path bar");
    // And the real files pass the bar they are now held to.
    for rel in ["../rust/src/sim/multimodel.rs", "../rust/src/serverless/loading.rs"] {
        let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(rel);
        let report = xtask::lint_paths(&[path]).expect("multimodel module should lint");
        assert!(report.clean(), "{rel} must stay lint-clean: {:?}", report.violations);
    }
}

#[test]
fn offload_scope_flags_the_store_antipatterns() {
    // The PR-10 scope extension: hash-order eviction (D1), wall-clock
    // transfer stamps (D2) and positional fetch-queue surgery (P1) in one
    // residency-cache fixture shaped like the expert store.
    let report = lint_fixture("offload_store_violation.rs");
    let ids = rule_ids(&report);
    assert!(ids.contains(&"D1"), "hash-order eviction must flag D1: {:?}", report.violations);
    assert!(ids.contains(&"D2"), "wall-clock stamp must flag D2: {:?}", report.violations);
    assert!(ids.contains(&"P1"), "positional fetch queue must flag P1: {:?}", report.violations);
}

#[test]
fn offload_scope_permits_the_engine_shape() {
    // The shape serverless/offload.rs actually uses: BTreeMap LRU keyed
    // by (stamp, shard), busy-until floats advanced from the sim clock,
    // back-of-queue push/pop for the pin scratch — clean under the same
    // directives.
    let report = lint_fixture("offload_store_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn lint_scope_covers_the_offload_store() {
    // Path classification, no directives: the expert store's per-layer
    // serve path is hot-path + sim-core, like loading.rs before it.
    let class = xtask::rules::classify("rust/src/serverless/offload.rs", &[]);
    assert!(class.hot_path, "offload.rs must be under P1");
    assert!(class.sim_core, "offload.rs must be under D1/D2");
    // And the real file passes the bar it is now held to.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src/serverless/offload.rs");
    let report = xtask::lint_paths(&[path]).expect("offload module should lint");
    assert!(report.clean(), "offload.rs must stay lint-clean: {:?}", report.violations);
}

#[test]
fn arena_scope_flags_positional_column_surgery() {
    // The PR-9 scope extension: the SoA arena's column Vecs are hot-path,
    // so shifting `Vec::remove` retirement is flagged…
    let report = lint_fixture("arena_violation.rs");
    assert_eq!(rule_ids(&report), vec!["P1"], "{:?}", report.violations);
}

#[test]
fn arena_scope_permits_index_sets_and_free_list_ops() {
    // …while the shapes the arena actually uses — BTreeSet index-set
    // insert/remove keyed by (key, slot) and LIFO free-list push/pop —
    // stay clean under the same classification.
    let report = lint_fixture("arena_clean.rs");
    assert!(report.clean(), "unexpected: {:?}", report.violations);
}

#[test]
fn lint_scope_covers_the_arena_but_not_the_frozen_cores() {
    // Path classification, no directives: the arena joined the P1 scope;
    // the frozen baseline cores (pre-PR-4 reference, PR-4 AoS) stay out —
    // they are what golden equivalence measures against, not hot paths.
    let arena = xtask::rules::classify("rust/src/router/arena.rs", &[]);
    assert!(arena.hot_path, "router/arena.rs must be under P1");
    assert!(arena.sim_core, "router/arena.rs must be under D1/D2");
    for frozen in ["rust/src/router/reference.rs", "rust/src/router/pr4.rs"] {
        let class = xtask::rules::classify(frozen, &[]);
        assert!(!class.hot_path, "{frozen} is a frozen baseline, not a hot path");
    }
    // And the real arena passes the bar it is now held to.
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src/router/arena.rs");
    let report = xtask::lint_paths(&[path]).expect("arena module should lint");
    assert!(report.clean(), "router/arena.rs must stay lint-clean: {:?}", report.violations);
}

#[test]
fn allow_suppresses_exactly_its_named_rule() {
    let report = lint_fixture("allow_scoped.rs");
    // The R1 allow on the unwrap line suppresses it and shows up in the
    // audit trail.
    assert_eq!(report.allows_used.len(), 1, "{:?}", report.allows_used);
    assert_eq!(report.allows_used[0].rule, "R1");
    // The allow(R1) on the float-equality line hides nothing: the D3
    // violation survives and the allow itself is reported as unused.
    let ids = rule_ids(&report);
    assert!(ids.contains(&"D3"), "D3 must survive a mismatched allow: {:?}", report.violations);
    assert!(
        report
            .violations
            .iter()
            .any(|v| v.rule == "allow" && v.msg.contains("unused allow(R1)")),
        "mismatched allow must be flagged unused: {:?}",
        report.violations
    );
    assert!(!ids.contains(&"R1"), "the audited unwrap must stay suppressed");
}

#[test]
fn lint_exits_with_findings_on_the_whole_fixture_dir() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let report = xtask::lint_paths(&[dir]).expect("fixture dir should lint");
    assert!(!report.clean(), "fixture dir must contain violations");
    // Findings carry file:line attribution for every violation.
    for v in &report.violations {
        assert!(v.file.ends_with(".rs") && v.line > 0, "bad attribution: {v:?}");
    }
}

/// The enforcement test: tier-1 (`cargo test -q`) fails if anyone
/// reintroduces a violation into rust/src, toolchain-only — no CI needed.
#[test]
fn repo_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../rust/src");
    let report = xtask::lint_paths(&[root]).expect("rust/src should lint");
    assert!(
        report.violations.is_empty(),
        "pallas-lint violations in rust/src:\n{:#?}",
        report.violations
    );
    assert!(
        report.allows_used.len() <= 5,
        "allow budget exceeded ({} > 5):\n{:#?}",
        report.allows_used.len(),
        report.allows_used
    );
    for a in &report.allows_used {
        assert!(a.msg.len() >= 5, "allow without a written reason: {a:?}");
    }
}
