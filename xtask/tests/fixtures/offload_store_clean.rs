// pallas-lint: treat-as(hot-path,sim-core)
//! Negative fixture for the expert-offloading store scope
//! (`serverless/offload.rs`): the engine shape that module uses — a
//! `BTreeMap` LRU keyed by `(stamp, shard)` with keyed remove/insert
//! (D1/P1-safe), per-device transfer engines as plain busy-until floats
//! advanced from the sim clock (D2-safe), and back-of-queue push/pop for
//! scratch (P1-safe).

use std::collections::BTreeMap;

pub struct ShardCache {
    pub by_stamp: BTreeMap<(u64, u32), f64>,
    pub stamp_of: BTreeMap<u32, u64>,
}

/// Keyed LRU touch: remove by key, reinsert at the new stamp — no
/// iteration order consumed, no positional shift.
pub fn touch(cache: &mut ShardCache, shard: u32, stamp: u64) {
    if let Some(old) = cache.stamp_of.insert(shard, stamp) {
        if let Some(gb) = cache.by_stamp.remove(&(old, shard)) {
            cache.by_stamp.insert((stamp, shard), gb);
        }
    }
}

/// Deterministic transfer serialization: the engine's busy-until instant
/// comes from the sim clock the caller passes in.
pub fn schedule_transfer(engine_free_s: &mut f64, start_s: f64, transfer_s: f64) -> f64 {
    let begin = if start_s > *engine_free_s { start_s } else { *engine_free_s };
    let done = begin + transfer_s;
    *engine_free_s = done;
    done
}

/// Scratch pins drain from the back: push/pop, never a positional remove.
pub fn unpin_all(pinned: &mut Vec<u32>, unpin: &mut impl FnMut(u32)) {
    while let Some(k) = pinned.pop() {
        unpin(k);
    }
}
