// pallas-lint: treat-as(hot-path,sim-core)
//! Positive fixture for the expert-offloading store scope
//! (`serverless/offload.rs`): a residency cache that (a) picks its
//! eviction victim by iterating a `HashMap` (D1 — the victim depends on
//! randomized hash order), (b) stamps transfer-engine recency off the
//! wall clock (D2 — two identical runs diverge), and (c) drains its
//! pending-fetch queue with positional `Vec` surgery (P1 — O(n) shifts
//! on the per-layer serve path).

use std::collections::HashMap;
use std::time::Instant;

pub struct ShardCache {
    pub resident: HashMap<u32, f64>,
}

/// D1: the eviction victim is whatever the hash iterator yields first.
pub fn evict_any(cache: &mut ShardCache) -> Option<u32> {
    let victim = cache.resident.iter().next().map(|(k, _)| *k);
    if let Some(k) = victim {
        cache.resident.remove(&k);
    }
    victim
}

/// D2: transfer recency stamped from the host clock, not the sim clock.
pub fn engine_stamp() -> Instant {
    Instant::now()
}

/// P1: FIFO via positional surgery on the pending-fetch queue.
pub fn next_fetch(pending: &mut Vec<u32>) -> u32 {
    pending.remove(0)
}
