// pallas-lint: treat-as(library)
//! D3 positive fixture: exact float equality on accumulating quantities.

pub fn ledger_settled(balance: f64) -> bool {
    balance == 0.0
}

pub fn clocks_differ(now_s: f64, deadline_s: f64) -> bool {
    now_s != deadline_s
}
