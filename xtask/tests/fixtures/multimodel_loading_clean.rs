// pallas-lint: treat-as(hot-path,sim-core)
//! Negative fixture for the multi-model loading/colocation scope
//! (`serverless/loading.rs`, `sim/multimodel.rs`): the warm-ledger shape
//! those modules use — a `BTreeMap` LRU keyed by `(stamp, model)` with
//! keyed remove/insert (D1/P1-safe), `Option::take` instead of positional
//! `Vec` surgery, and no wall clock anywhere (D2-safe).

use std::collections::BTreeMap;

pub struct WarmLedger {
    pub by_stamp: BTreeMap<(u64, u32), f64>,
    pub stamp_of: BTreeMap<u32, u64>,
}

/// Keyed LRU touch: remove by key, reinsert at the new stamp — no
/// iteration order consumed, no positional shift.
pub fn touch(ledger: &mut WarmLedger, model: u32, now_stamp: u64) {
    if let Some(old) = ledger.stamp_of.insert(model, now_stamp) {
        if let Some(gb) = ledger.by_stamp.remove(&(old, model)) {
            ledger.by_stamp.insert((now_stamp, model), gb);
        }
    }
}

/// Retiring an in-flight slot: `Option::take`, not `Vec::remove`.
pub fn retire(flights: &mut [Option<u32>], idx: usize) -> Option<u32> {
    flights[idx].take()
}
