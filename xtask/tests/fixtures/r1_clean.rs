// pallas-lint: treat-as(library)
//! R1 negative fixture: fallible signatures, defaulted options, and
//! test-module unwraps are all fine.

pub fn parse_port(s: &str) -> Result<u16, std::num::ParseIntError> {
    s.parse()
}

pub fn or_default(opt: Option<u32>) -> u32 {
    opt.unwrap_or(0)
}

pub fn or_computed(opt: Option<u32>) -> u32 {
    opt.unwrap_or_else(|| 7)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_is_fine_in_tests() {
        let v: Option<u32> = Some(3);
        assert_eq!(v.unwrap(), 3);
        let w: Option<u32> = Some(4);
        assert_eq!(w.expect("present"), 4);
    }
}
