// pallas-lint: treat-as(hot-path)
//! P1 positive fixture: positional Vec surgery on a hot path.

pub fn drop_first(queue: &mut Vec<u64>) -> u64 {
    queue.remove(0)
}

pub fn drop_at(queue: &mut Vec<u64>, i: usize) -> u64 {
    queue.swap_remove(i)
}

pub fn push_front(queue: &mut Vec<u64>, v: u64) {
    queue.insert(0, v);
}
