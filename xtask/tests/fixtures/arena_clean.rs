// pallas-lint: treat-as(hot-path)
//! Arena negative fixture: the PR-9 SoA shapes — ordered index-sets over
//! u32 slots and a LIFO free-list driven by back-of-Vec push/pop. All
//! keyed or amortized-O(1); nothing positional.

use std::collections::BTreeSet;

pub fn admit(running: &mut BTreeSet<(u64, u32)>, key: u64, slot: u32) {
    running.insert((key, slot));
}

pub fn retire(running: &mut BTreeSet<(u64, u32)>, key: u64, slot: u32) -> bool {
    running.remove(&(key, slot))
}

pub fn alloc_slot(free: &mut Vec<u32>, next: &mut u32) -> u32 {
    match free.pop() {
        Some(slot) => slot,
        None => {
            let slot = *next;
            *next += 1;
            slot
        }
    }
}

pub fn release_slot(free: &mut Vec<u32>, slot: u32) {
    free.push(slot);
}
