// pallas-lint: treat-as(hot-path)
//! P1 negative fixture: keyed removal via a BTreeMap index and back-of-Vec
//! push/pop — the shapes PR 4 moved the hot paths onto.

use std::collections::BTreeMap;

pub fn retire(active: &mut BTreeMap<u64, u32>, key: u64) -> Option<u32> {
    active.remove(&key)
}

pub fn pop_back(queue: &mut Vec<u64>) -> Option<u64> {
    queue.pop()
}

pub fn append(queue: &mut Vec<u64>, v: u64) {
    queue.push(v);
}
