// pallas-lint: treat-as(library)
//! R1 positive fixture: unwrap/expect/panic! in library code.

pub fn parse_port(s: &str) -> u16 {
    s.parse().unwrap()
}

pub fn must(opt: Option<u32>) -> u32 {
    opt.expect("value missing")
}

pub fn die() -> ! {
    panic!("boom")
}
