// pallas-lint: treat-as(hot-path,sim-core)
//! Positive fixture for the multi-model loading/colocation scope: a warm
//! ledger that (a) evicts by iterating a `HashMap` (D1 — the victim
//! depends on randomized hash order), (b) timestamps recency off the wall
//! clock (D2 — two identical runs diverge), and (c) retires queue slots
//! with positional `Vec` surgery (P1 — O(n) shifts on the hot path).

use std::collections::HashMap;
use std::time::Instant;

pub struct WarmLedger {
    pub resident: HashMap<u32, f64>,
}

/// D1: the eviction victim is whatever the hash iterator yields first.
pub fn evict_any(ledger: &mut WarmLedger) -> Option<u32> {
    let victim = ledger.resident.iter().next().map(|(m, _)| *m);
    if let Some(m) = victim {
        ledger.resident.remove(&m);
    }
    victim
}

/// D2: recency stamped from the host clock instead of the sim clock.
pub fn stamp() -> Instant {
    Instant::now()
}

/// P1: positional surgery on the pending-request queue.
pub fn retire(pending: &mut Vec<u32>, idx: usize) -> u32 {
    pending.remove(idx)
}
