// pallas-lint: treat-as(sim-core)
//! D2 negative fixture: time comes in as a sim-clock argument, randomness
//! from a seed supplied by the caller.

pub fn age_s(now_s: f64, arrival_s: f64) -> f64 {
    now_s - arrival_s
}

pub fn mix(seed: u64) -> u64 {
    seed.wrapping_mul(0x9e37_79b9_7f4a_7c15)
}
