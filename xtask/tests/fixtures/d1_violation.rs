// pallas-lint: treat-as(sim-core)
//! D1 positive fixture: ordering-dependent iteration over hash collections.

use std::collections::HashMap;

pub fn total(load: &HashMap<u64, f64>) -> f64 {
    let mut sum = 0.0;
    for (_gpu, l) in load.iter() {
        sum += l;
    }
    sum
}

pub fn count_pending(pending: HashMap<u64, u32>) -> usize {
    let mut n = 0;
    for _entry in pending {
        n += 1;
    }
    n
}
