// pallas-lint: treat-as(hot-path)
//! P1 positive fixture: an "event queue" kept time-ordered by positional
//! Vec surgery — O(n) per schedule/pop, the shape the event driver's
//! binary heap exists to avoid.

pub struct Event {
    pub t_bits: u64,
    pub seq: u64,
}

pub fn pop_next(events: &mut Vec<Event>) -> Event {
    events.remove(0)
}

pub fn schedule_front(events: &mut Vec<Event>, e: Event) {
    events.insert(0, e);
}
