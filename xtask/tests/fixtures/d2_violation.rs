// pallas-lint: treat-as(sim-core)
//! D2 positive fixture: wall-clock time observed on the sim path.

use std::time::Instant;

pub fn stamp_ms() -> u128 {
    let t0 = Instant::now();
    t0.elapsed().as_millis()
}
