// pallas-lint: treat-as(hot-path)
//! P1 negative fixture: the event-scheduling shape `sim/event.rs` uses —
//! a min-heap (`BinaryHeap<Reverse<_>>`) keyed on `(t_bits, seq)`, with
//! O(log n) push/pop and no positional surgery.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

#[derive(PartialEq, Eq, PartialOrd, Ord)]
pub struct Event {
    pub t_bits: u64,
    pub seq: u64,
}

pub fn pop_next(heap: &mut BinaryHeap<Reverse<Event>>) -> Option<Event> {
    heap.pop().map(|Reverse(e)| e)
}

pub fn schedule(heap: &mut BinaryHeap<Reverse<Event>>, e: Event) {
    heap.push(Reverse(e));
}
