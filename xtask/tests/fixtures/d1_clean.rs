// pallas-lint: treat-as(sim-core)
//! D1 negative fixture: keyed lookup/insert/remove on a hash collection is
//! fine — only iteration order is nondeterministic.

use std::collections::{BTreeMap, HashMap};

pub fn locate(loc: &HashMap<u64, usize>, key: u64) -> Option<usize> {
    loc.get(&key).copied()
}

pub fn record(loc: &mut HashMap<u64, usize>, key: u64, gpu: usize) {
    loc.insert(key, gpu);
}

pub fn ordered_sum(load: &BTreeMap<u64, u64>) -> u64 {
    load.values().sum()
}
