// pallas-lint: treat-as(library)
//! Allow-scoping fixture: an inline allow suppresses exactly its named
//! rule on its own line (or the line below), and nothing else.

pub fn audited(opt: Option<u32>) -> u32 {
    opt.unwrap() // pallas-lint: allow(R1) — fixture: audited exemption demo
}

pub fn wrong_rule(x: f64) -> bool {
    x == 0.0 // pallas-lint: allow(R1) — fixture: wrong id must not hide D3
}
