// pallas-lint: treat-as(library)
//! D3 negative fixture: epsilon comparison, integer/str/char equality, and
//! debug_assert! bodies are all fine.

pub fn ledger_settled(balance: f64, eps: f64) -> bool {
    balance.abs() < eps
}

pub fn mode_is_strict(mode: &str) -> bool {
    mode == "strict"
}

pub fn all_done(done: usize, total: usize) -> bool {
    done == total
}

pub fn is_dash(c: u8) -> bool {
    c == b'-'
}

pub fn checked_start(balance: f64) {
    debug_assert!(balance == 0.0, "ledger must start settled");
}
