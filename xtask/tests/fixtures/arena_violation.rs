// pallas-lint: treat-as(hot-path)
//! Arena positive fixture: positional column surgery — the AoS habit the
//! slot arena exists to kill. Removing a retired sequence by shifting a
//! column Vec is O(live) per retirement and invalidates every slot index
//! behind it.

pub fn retire_by_position(kv_tokens: &mut Vec<u64>, pos: usize) -> u64 {
    kv_tokens.remove(pos)
}
