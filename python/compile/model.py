"""L2: TinyMoE — a real decoder-only MoE transformer in JAX (build-time only).

Two forward formulations over the *same* parameters:

* ``forward``      — monolithic: the whole model as one jit-able function
                     (lowered to ``tiny_model.hlo.txt``; the Rust runtime uses
                     it as the numerical ground truth for decomposed serving).
* component fns    — ``embed_fn`` / ``attn_fn`` / ``gate_fn`` / ``expert_fn``
                     / ``head_fn``: the decomposition the Rust coordinator
                     serves. Each expert FFN is its *own* artifact invocation
                     = one serverless expert function instance (DESIGN.md
                     key decision 1). The residual combine
                     ``out = h + sum_e w[:,e] * y_e`` is pure data movement
                     and is performed by the coordinator in f32, in the same
                     expert order as the monolithic loop, so the two paths
                     agree to float tolerance.

Both paths route through the L1 Pallas kernels (``kernels.moe_ffn``,
``kernels.topk_gate``), so the kernels lower into every emitted HLO artifact.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels.moe_ffn import expert_ffn
from .kernels.topk_gate import topk_gate


@dataclass(frozen=True)
class TinyMoEConfig:
    """TinyMoE architecture hyperparameters (fixed AOT shapes)."""

    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    d_ff: int = 256
    n_layers: int = 4
    n_experts: int = 8
    top_k: int = 2
    batch: int = 4
    seq: int = 32
    # Per-instance token capacity of one serverless expert function. The
    # coordinator spawns ceil(load / capacity) instances per expert — the
    # static-shape analogue of GShard capacity factors (DESIGN.md
    # §Hardware-Adaptation).
    capacity: int = 64

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def n_tokens(self) -> int:
        """Flattened token-batch size routed per MoE layer."""
        return self.batch * self.seq

    def param_specs(self):
        """Ordered (name, shape) for every model tensor.

        This order *is* the artifact parameter ABI: the Rust runtime feeds
        weights positionally from the manifest, so it must never be
        reordered silently (the manifest records it explicitly).
        """
        d, f, e, v = self.d_model, self.d_ff, self.n_experts, self.vocab
        specs = [("wemb", (v, d)), ("wpos", (self.seq, d))]
        for l in range(self.n_layers):
            p = f"layer{l}."
            specs += [
                (p + "ln1.g", (d,)),
                (p + "ln1.b", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "ln2.g", (d,)),
                (p + "ln2.b", (d,)),
                (p + "wg", (d, e)),
                (p + "w1", (e, d, f)),
                (p + "w2", (e, f, d)),
                (p + "w3", (e, d, f)),
            ]
        specs += [("lnf.g", (d,)), ("lnf.b", (d,)), ("whead", (d, v))]
        return specs


def init_params(cfg: TinyMoEConfig, seed: int = 0):
    """Deterministic scaled-gaussian init; returns {name: array} (f32)."""
    params = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in cfg.param_specs():
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith(".b"):
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            params[name] = jax.random.normal(sub, shape, jnp.float32) * scale
    return params


def layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


# ---------------------------------------------------------------------------
# Decomposed component functions — one HLO artifact each.
# ---------------------------------------------------------------------------


def embed_fn(cfg, tokens, wemb, wpos):
    """[B,T] i32 -> [B,T,D]: token embedding + learned positions."""
    x = jnp.take(wemb, tokens, axis=0)
    return x + wpos[None, :, :]


def attn_fn(cfg, x, len_mask, ln1g, ln1b, wq, wk, wv, wo, ln2g, ln2b):
    """Pre-LN causal multi-head attention block.

    Args:
      x:        [B,T,D] block input.
      len_mask: [B,T] f32, 1.0 for valid tokens.
    Returns:
      (h, moe_in): h = x + attn(ln1(x)) is the residual stream [B,T,D];
      moe_in = ln2(h) flattened to [B*T, D] is the MoE-layer input the gate
      and the serverless experts consume.
    """
    b, t, d = x.shape
    nh, dh = cfg.n_heads, cfg.d_head
    xn = layer_norm(x, ln1g, ln1b)
    q = (xn @ wq).reshape(b, t, nh, dh).transpose(0, 2, 1, 3)
    k = (xn @ wk).reshape(b, t, nh, dh).transpose(0, 2, 1, 3)
    v = (xn @ wv).reshape(b, t, nh, dh).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.asarray(dh, x.dtype))
    causal = jnp.tril(jnp.ones((t, t), x.dtype))
    mask = causal[None, None, :, :] * len_mask[:, None, None, :]
    scores = jnp.where(mask > 0, scores, jnp.asarray(-1e9, x.dtype))
    attn = jax.nn.softmax(scores, axis=-1)
    ctx = (attn @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    h = x + ctx @ wo
    moe_in = layer_norm(h, ln2g, ln2b).reshape(b * t, d)
    return h, moe_in


def gate_fn(cfg, moe_in, wg):
    """[N,D] -> [N,E] sparse routing weights via the fused Pallas gate."""
    return topk_gate(moe_in, wg, cfg.top_k)


def expert_fn(cfg, xc, w1, w2, w3):
    """One serverless expert invocation: [C,D] tile via the Pallas FFN."""
    return expert_ffn(xc, w1, w2, w3)


def head_fn(cfg, h, lnfg, lnfb, whead):
    """[B,T,D] -> [B,T,V] logits (final LN + LM head)."""
    return layer_norm(h, lnfg, lnfb) @ whead


# ---------------------------------------------------------------------------
# Monolithic forward (ground truth) + intermediates for predictor training.
# ---------------------------------------------------------------------------


def _moe_layer(cfg, moe_in, weights, w1, w2, w3):
    """Dense-but-exact MoE combine: sum_e w[:,e] * ffn_e(moe_in).

    Non-top-k weights are exactly zero, so computing every expert over every
    token is numerically identical to the routed/decomposed execution
    (matmuls are row-independent); the accumulation order over experts
    matches the Rust coordinator's combine loop.
    """
    out = jnp.zeros_like(moe_in)
    for e in range(cfg.n_experts):
        y = expert_ffn(moe_in, w1[e], w2[e], w3[e])
        out = out + weights[:, e : e + 1] * y
    return out


def forward(cfg, params, tokens, len_mask):
    """Monolithic TinyMoE forward: [B,T] i32, [B,T] f32 -> [B,T,V] logits."""
    x = embed_fn(cfg, tokens, params["wemb"], params["wpos"])
    b, t, d = x.shape
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h, moe_in = attn_fn(
            cfg, x, len_mask,
            params[p + "ln1.g"], params[p + "ln1.b"],
            params[p + "wq"], params[p + "wk"], params[p + "wv"], params[p + "wo"],
            params[p + "ln2.g"], params[p + "ln2.b"],
        )
        weights = gate_fn(cfg, moe_in, params[p + "wg"])
        moe_out = _moe_layer(cfg, moe_in, weights,
                             params[p + "w1"], params[p + "w2"], params[p + "w3"])
        x = h + moe_out.reshape(b, t, d)
    return head_fn(cfg, x, params["lnf.g"], params["lnf.b"], params["whead"])


def forward_with_intermediates(cfg, params, tokens, len_mask):
    """Forward that also returns per-layer (moe_in, routing weights).

    Used by ``finetune.py`` to build the predictor dataset: the speculative
    predictor maps layer-l hidden states to layer-(l+d) routing.
    """
    x = embed_fn(cfg, tokens, params["wemb"], params["wpos"])
    b, t, d = x.shape
    moe_ins, routes = [], []
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h, moe_in = attn_fn(
            cfg, x, len_mask,
            params[p + "ln1.g"], params[p + "ln1.b"],
            params[p + "wq"], params[p + "wk"], params[p + "wv"], params[p + "wo"],
            params[p + "ln2.g"], params[p + "ln2.b"],
        )
        weights = gate_fn(cfg, moe_in, params[p + "wg"])
        moe_ins.append(moe_in)
        routes.append(weights)
        moe_out = _moe_layer(cfg, moe_in, weights,
                             params[p + "w1"], params[p + "w2"], params[p + "w3"])
        x = h + moe_out.reshape(b, t, d)
    logits = head_fn(cfg, x, params["lnf.g"], params["lnf.b"], params["whead"])
    return logits, moe_ins, routes
