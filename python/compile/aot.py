"""AOT pipeline: lower TinyMoE (monolithic + decomposed) to HLO text.

Interchange is HLO **text**, not ``.serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Emitted into ``artifacts/``:

  tiny_model.hlo.txt    monolithic forward (ground truth for e2e validation)
  tiny_embed.hlo.txt    tokens -> residual stream
  tiny_attn.hlo.txt     per-layer attention block -> (h, moe_in)  [shared by
                        all layers: identical shapes, per-layer weights fed
                        positionally by the coordinator]
  tiny_gate.hlo.txt     moe_in -> sparse routing weights (Pallas top-k gate);
                        doubles as the *predictor* artifact — the speculative
                        predictor is the same network with fine-tuned weights
  tiny_expert.hlo.txt   one serverless expert function: [capacity, D] tile
                        through the Pallas SwiGLU FFN
  tiny_head.hlo.txt     residual stream -> logits
  weights.bin           model tensors (manifest-ordered raw f32/i32)
  manifest.json         config + tensor table + artifact ABI

Run once via ``make artifacts``; Python never runs on the request path.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .iobin import BinWriter, write_json


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_artifacts(cfg: M.TinyMoEConfig):
    """Lower every component; returns {artifact_name: (hlo_text, abi)}."""
    b, t, d = cfg.batch, cfg.seq, cfg.d_model
    n, e, f, v, c = cfg.n_tokens, cfg.n_experts, cfg.d_ff, cfg.vocab, cfg.capacity
    arts = {}

    def lower(name, fn, runtime_inputs, weight_params, per="model", outputs=1):
        """runtime_inputs: [(name, shape, dtype)]; weight_params: [(name, shape)]."""
        in_specs = [_spec(s, dt) for (_, s, dt) in runtime_inputs]
        w_specs = [_spec(s) for (_, s) in weight_params]
        lowered = jax.jit(fn).lower(*in_specs, *w_specs)
        arts[name] = (
            to_hlo_text(lowered),
            {
                "file": f"{name}.hlo.txt",
                "runtime_inputs": [
                    {"name": nm, "shape": list(s), "dtype": "i32" if dt == jnp.int32 else "f32"}
                    for (nm, s, dt) in runtime_inputs
                ],
                "weight_params": [
                    {"name": nm, "shape": list(s)} for (nm, s) in weight_params
                ],
                # "model": weights are the named global tensors;
                # "layer": names are suffixes resolved as layer{l}.<name>;
                # "expert": names resolved as layer{l}.<name> sliced at [e].
                "weight_scope": per,
                "outputs": outputs,
            },
        )

    # Monolithic: runtime inputs + every tensor in param_specs order.
    specs = cfg.param_specs()

    def mono(tokens, len_mask, *flat):
        params = {nm: w for (nm, _), w in zip(specs, flat)}
        return M.forward(cfg, params, tokens, len_mask)

    lower(
        "tiny_model", mono,
        [("tokens", (b, t), jnp.int32), ("len_mask", (b, t), jnp.float32)],
        [(nm, sh) for nm, sh in specs],
    )

    lower(
        "tiny_embed",
        lambda tokens, wemb, wpos: M.embed_fn(cfg, tokens, wemb, wpos),
        [("tokens", (b, t), jnp.int32)],
        [("wemb", (v, d)), ("wpos", (t, d))],
    )

    lower(
        "tiny_attn",
        lambda x, m, *w: M.attn_fn(cfg, x, m, *w),
        [("x", (b, t, d), jnp.float32), ("len_mask", (b, t), jnp.float32)],
        [("ln1.g", (d,)), ("ln1.b", (d,)), ("wq", (d, d)), ("wk", (d, d)),
         ("wv", (d, d)), ("wo", (d, d)), ("ln2.g", (d,)), ("ln2.b", (d,))],
        per="layer",
        outputs=2,
    )

    lower(
        "tiny_gate",
        lambda moe_in, wg: M.gate_fn(cfg, moe_in, wg),
        [("moe_in", (n, d), jnp.float32)],
        [("wg", (d, e))],
        per="layer",
    )

    lower(
        "tiny_expert",
        lambda xc_, w1, w2, w3: M.expert_fn(cfg, xc_, w1, w2, w3),
        [("xc", (c, d), jnp.float32)],
        [("w1", (d, f)), ("w2", (f, d)), ("w3", (d, f))],
        per="expert",
    )

    lower(
        "tiny_head",
        lambda h, g_, b_, wh: M.head_fn(cfg, h, g_, b_, wh),
        [("h", (b, t, d), jnp.float32)],
        [("lnf.g", (d,)), ("lnf.b", (d,)), ("whead", (d, v))],
    )

    return arts


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = M.TinyMoEConfig()
    params = M.init_params(cfg, seed=args.seed)

    arts = lower_artifacts(cfg)
    for name, (text, _) in arts.items():
        path = f"{args.out}/{name}.hlo.txt"
        with open(path, "w") as fh:
            fh.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    w = BinWriter("weights.bin")
    for name, _ in cfg.param_specs():
        w.add(name, params[name])
    w.write(args.out)

    manifest = {
        "model": {
            "name": "tiny-moe",
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "n_layers": cfg.n_layers, "n_experts": cfg.n_experts,
            "top_k": cfg.top_k, "batch": cfg.batch, "seq": cfg.seq,
            "capacity": cfg.capacity, "seed": args.seed,
        },
        "tensors": w.table,
        "artifacts": {name: abi for name, (_, abi) in arts.items()},
    }
    write_json(args.out, "manifest.json", manifest)
    print(f"wrote {args.out}/weights.bin ({w.offset} bytes), manifest.json")


if __name__ == "__main__":
    main()
