"""Artifact binary I/O shared by aot.py and finetune.py.

Weights interchange format (consumed by ``rust/src/tensor/store.rs``):

* ``<name>.bin``      — concatenated little-endian raw tensor data.
* ``manifest.json``   — model config, tensor table (name -> dtype, shape,
                        byte offset, nbytes, which .bin file), and the
                        artifact table (name -> HLO file, runtime inputs,
                        ordered weight parameters).

A bespoke format (rather than .npz) keeps the Rust loader dependency-free:
offsets + raw f32/i32 bytes, nothing else.
"""

import json

import numpy as np


class BinWriter:
    """Appends tensors to a raw .bin blob and records their table entries."""

    def __init__(self, bin_name: str):
        self.bin_name = bin_name
        self.chunks = []
        self.table = {}
        self.offset = 0

    def add(self, name: str, arr) -> None:
        arr = np.asarray(arr)
        if arr.dtype == np.float64:
            arr = arr.astype(np.float32)
        assert arr.dtype in (np.float32, np.int32), f"{name}: {arr.dtype}"
        data = np.ascontiguousarray(arr).tobytes()
        self.table[name] = {
            "dtype": "f32" if arr.dtype == np.float32 else "i32",
            "shape": list(arr.shape),
            "offset": self.offset,
            "nbytes": len(data),
            "bin": self.bin_name,
        }
        self.chunks.append(data)
        self.offset += len(data)

    def write(self, out_dir: str) -> None:
        with open(f"{out_dir}/{self.bin_name}", "wb") as f:
            for c in self.chunks:
                f.write(c)


def write_json(out_dir: str, name: str, obj) -> None:
    with open(f"{out_dir}/{name}", "w") as f:
        json.dump(obj, f, indent=1, sort_keys=True)


def read_json(path: str):
    with open(path) as f:
        return json.load(f)
