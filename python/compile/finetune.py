"""Predictor fine-tuning (paper §4.1 + §5 "Fine-tuning predictors").

The Expert Load Predictor is a *replica of the gate network*: for a
prediction distance ``d`` it consumes layer-l hidden states and predicts the
routing of layer l+d (exploiting residual-stream similarity, Fig. 6a).

This module, run once at build time (``make artifacts``):

1. Builds the fine-tuning dataset exactly as §5 describes — collect each MoE
   layer's input hidden states + gate outputs from forward passes over a
   corpus (synthetic seeded token sequences), split 7:3 train/test.
2. Measures the *pretrained* predictor (layer-(l+d) gate applied to layer-l
   states — this is Mixtral-offloading's scheme) per (layer, distance).
3. Fine-tunes a gate replica per (layer, distance) with Adam on a KL loss
   against the actual layer-(l+d) gate distribution — same architecture and
   parameter count as the gate itself (Table 2's "Ours" column).
4. Trains a ProMoE-style from-scratch MLP predictor (bigger, Table 2's
   "ProMoE" column) on the same data for the Fig. 11 comparison.
5. Exports fine-tuned weights (``predictors.bin``) and a measured accuracy
   profile (``predictor_profile.json``) that the Rust coordinator loads for
   layer-aware predictor selection, and that the Fig. 6/7/11/12 benches
   replot.

Layer awareness (§4.1): layers whose pretrained accuracy already exceeds the
threshold ``h`` keep the raw gate replica; only layers below ``h`` take the
fine-tuned weights. Both accuracies are recorded.
"""

import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .iobin import BinWriter, read_json, write_json
from .kernels import ref

THRESHOLD_H = 0.8  # layer-aware fine-tuning target accuracy (§4.1)


# ---------------------------------------------------------------------------
# Data collection (pure-jnp twin of the model for speed; identical math).
# ---------------------------------------------------------------------------


def collect_dataset(cfg, params, n_batches: int, seed: int):
    """Forward passes over a synthetic corpus; returns per-layer states/routes.

    Returns (moe_ins, routes): lists of [n_batches*N, D] and [.., E] arrays.
    """
    key = jax.random.PRNGKey(seed)
    moe_ins = [[] for _ in range(cfg.n_layers)]
    routes = [[] for _ in range(cfg.n_layers)]

    @jax.jit
    def step(tokens, len_mask):
        x = M.embed_fn(cfg, tokens, params["wemb"], params["wpos"])
        b, t, d = x.shape
        outs = []
        for l in range(cfg.n_layers):
            p = f"layer{l}."
            h, moe_in = M.attn_fn(
                cfg, x, len_mask,
                params[p + "ln1.g"], params[p + "ln1.b"],
                params[p + "wq"], params[p + "wk"], params[p + "wv"], params[p + "wo"],
                params[p + "ln2.g"], params[p + "ln2.b"],
            )
            w = ref.topk_gate_ref(moe_in, params[p + "wg"], cfg.top_k)
            out = jnp.zeros_like(moe_in)
            for e in range(cfg.n_experts):
                y = ref.expert_ffn_ref(
                    moe_in, params[p + "w1"][e], params[p + "w2"][e], params[p + "w3"][e]
                )
                out = out + w[:, e : e + 1] * y
            x = h + out.reshape(b, t, d)
            outs.append((moe_in, w))
        return outs

    for _ in range(n_batches):
        key, k1, k2 = jax.random.split(key, 3)
        tokens = jax.random.randint(k1, (cfg.batch, cfg.seq), 0, cfg.vocab, jnp.int32)
        lens = jax.random.randint(k2, (cfg.batch,), cfg.seq // 2, cfg.seq + 1)
        len_mask = (jnp.arange(cfg.seq)[None, :] < lens[:, None]).astype(jnp.float32)
        for l, (mi, w) in enumerate(step(tokens, len_mask)):
            moe_ins[l].append(np.asarray(mi))
            routes[l].append(np.asarray(w))

    return (
        [np.concatenate(v) for v in moe_ins],
        [np.concatenate(v) for v in routes],
    )


# ---------------------------------------------------------------------------
# Metrics.
# ---------------------------------------------------------------------------


def topk_sets(weights, k):
    """[N,E] routing weights -> [N,k] sorted expert indices."""
    return np.sort(np.argsort(-weights, axis=-1)[:, :k], axis=-1)


def topk_overlap_acc(pred_scores, actual_weights, k) -> float:
    """Mean |predicted top-k ∩ actual top-k| / k (the §6.3 accuracy metric)."""
    pred = topk_sets(pred_scores, k)
    act = topk_sets(actual_weights, k)
    inter = np.array(
        [len(set(p) & set(a)) for p, a in zip(pred, act)], dtype=np.float64
    )
    return float(inter.mean() / k)


def load_pearson(pred_scores, actual_weights, k, group=128):
    """Pearson r between predicted and actual per-expert load counts.

    Loads are token counts per expert aggregated over groups of ``group``
    tokens (one serving batch), mirroring Fig. 12's predicted-vs-actual
    correlation points. Returns (r, points) where points is a list of
    (predicted_load, actual_load) pairs.
    """
    e = actual_weights.shape[1]
    n = (pred_scores.shape[0] // group) * group
    pts = []
    for s in range(0, n, group):
        p = topk_sets(pred_scores[s : s + group], k)
        a = topk_sets(actual_weights[s : s + group], k)
        pl = np.bincount(p.ravel(), minlength=e)
        al = np.bincount(a.ravel(), minlength=e)
        pts += list(zip(pl.tolist(), al.tolist()))
    x = np.array([p for p, _ in pts], dtype=np.float64)
    y = np.array([a for _, a in pts], dtype=np.float64)
    r = float(np.corrcoef(x, y)[0, 1]) if x.std() > 0 and y.std() > 0 else 0.0
    return r, pts


def mean_cosine(a, b) -> float:
    num = (a * b).sum(-1)
    den = np.linalg.norm(a, axis=-1) * np.linalg.norm(b, axis=-1) + 1e-12
    return float((num / den).mean())


# ---------------------------------------------------------------------------
# Training (hand-rolled Adam; optax is not available offline).
# ---------------------------------------------------------------------------


def adam_train(loss_fn, params0, data, steps, lr, batch, seed):
    """Minimal Adam loop over pytree params; data = tuple of arrays."""
    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    m = jax.tree_util.tree_map(jnp.zeros_like, params0)
    v = jax.tree_util.tree_map(jnp.zeros_like, params0)
    p = params0
    b1, b2, eps = 0.9, 0.999, 1e-8
    n = data[0].shape[0]
    rng = np.random.default_rng(seed)
    for t in range(1, steps + 1):
        idx = rng.integers(0, n, size=batch)
        mb = tuple(d[idx] for d in data)
        _, g = grad_fn(p, *mb)
        m = jax.tree_util.tree_map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = jax.tree_util.tree_map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
        mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1**t), m)
        vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2**t), v)
        p = jax.tree_util.tree_map(
            lambda p_, mh_, vh_: p_ - lr * mh_ / (jnp.sqrt(vh_) + eps), p, mh, vh
        )
    return p


def kl_to_actual(wg, x, target_probs):
    """KL(target || softmax(x @ wg)) — distillation onto the future gate."""
    logits = x @ wg
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -(target_probs * logp).sum(-1).mean()


def mlp_loss(p, x, target_probs):
    h = jnp.tanh(x @ p["w0"] + p["b0"])
    logp = jax.nn.log_softmax(h @ p["w1"] + p["b1"], axis=-1)
    return -(target_probs * logp).sum(-1).mean()


# ---------------------------------------------------------------------------
# Main pipeline.
# ---------------------------------------------------------------------------


def run(out_dir: str, n_batches: int, steps: int, seed: int):
    manifest = read_json(f"{out_dir}/manifest.json")
    mc = manifest["model"]
    cfg = M.TinyMoEConfig(
        vocab=mc["vocab"], d_model=mc["d_model"], n_heads=mc["n_heads"],
        d_ff=mc["d_ff"], n_layers=mc["n_layers"], n_experts=mc["n_experts"],
        top_k=mc["top_k"], batch=mc["batch"], seq=mc["seq"],
        capacity=mc["capacity"],
    )
    params = M.init_params(cfg, seed=mc["seed"])

    moe_ins, routes = collect_dataset(cfg, params, n_batches, seed=seed + 1)
    n = moe_ins[0].shape[0]
    n_train = int(n * 0.7)  # 7:3 split per §5

    w = BinWriter("predictors.bin")
    entries = []
    d_model, n_exp, k = cfg.d_model, cfg.n_experts, cfg.top_k

    for l in range(cfg.n_layers):
        for d in range(1, cfg.n_layers - l):
            src = moe_ins[l]
            tgt_route = routes[l + d]
            tgt_probs = np.asarray(
                jax.nn.softmax(
                    jnp.asarray(moe_ins[l + d]) @ params[f"layer{l + d}.wg"], axis=-1
                )
            )
            xtr, xte = src[:n_train], src[n_train:]
            ptr = tgt_probs[:n_train]
            rte = tgt_route[n_train:]

            cos = mean_cosine(src, moe_ins[l + d])

            # Pretrained = Mixtral-offloading: reuse the future gate as-is.
            wg_pre = np.asarray(params[f"layer{l + d}.wg"])
            acc_pre = topk_overlap_acc(xte @ wg_pre, rte, k)
            r_pre, _ = load_pearson(xte @ wg_pre, rte, k)

            # Layer-aware fine-tuning: only layers under the threshold train.
            finetuned = acc_pre < THRESHOLD_H
            if finetuned:
                wg_ft = adam_train(
                    kl_to_actual, jnp.asarray(wg_pre),
                    (jnp.asarray(xtr), jnp.asarray(ptr)),
                    steps=steps, lr=3e-3, batch=512, seed=seed + 7 * l + d,
                )
                wg_ft = np.asarray(wg_ft)
            else:
                wg_ft = wg_pre
            acc_ft = topk_overlap_acc(xte @ wg_ft, rte, k)
            r_ft, pts = load_pearson(xte @ wg_ft, rte, k)

            # ProMoE-style from-scratch MLP (larger footprint, Fig. 11).
            key = jax.random.PRNGKey(seed + 100 + 7 * l + d)
            k0, k1 = jax.random.split(key)
            hidden = 64
            mlp0 = {
                "w0": jax.random.normal(k0, (d_model, hidden)) * 0.1,
                "b0": jnp.zeros((hidden,)),
                "w1": jax.random.normal(k1, (hidden, n_exp)) * 0.1,
                "b1": jnp.zeros((n_exp,)),
            }
            mlp = adam_train(
                mlp_loss, mlp0, (jnp.asarray(xtr), jnp.asarray(ptr)),
                steps=steps, lr=3e-3, batch=512, seed=seed + 200 + 7 * l + d,
            )
            h = np.tanh(xte @ np.asarray(mlp["w0"]) + np.asarray(mlp["b0"]))
            acc_promoe = topk_overlap_acc(
                h @ np.asarray(mlp["w1"]) + np.asarray(mlp["b1"]), rte, k
            )

            w.add(f"pred.l{l}.d{d}.wg", wg_ft)
            entries.append({
                "layer": l, "distance": d, "cos_sim": cos,
                "acc_pretrained": acc_pre, "acc_finetuned": acc_ft,
                "acc_promoe": acc_promoe, "load_pearson_pre": r_pre,
                "load_pearson_ft": r_ft, "finetuned": bool(finetuned),
                "corr_points": pts[: 4 * n_exp],
            })
            print(
                f"l={l} d={d} cos={cos:.3f} pre={acc_pre:.3f} "
                f"ft={acc_ft:.3f} promoe={acc_promoe:.3f} r={r_ft:.3f}"
            )

    w.write(out_dir)
    profile = {
        "threshold": THRESHOLD_H,
        "entries": entries,
        "tensors": w.table,
        "footprints_bytes": {
            "ours_per_predictor": d_model * n_exp * 4,
            "mixtral_offloading_per_predictor": d_model * n_exp * 4,
            "promoe_per_predictor": (d_model * 64 + 64 + 64 * n_exp + n_exp) * 4,
        },
    }
    write_json(out_dir, "predictor_profile.json", profile)
    print(f"wrote {out_dir}/predictors.bin, predictor_profile.json "
          f"({len(entries)} predictors)")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--batches", type=int, default=48)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.out, args.batches, args.steps, args.seed)


if __name__ == "__main__":
    main()
