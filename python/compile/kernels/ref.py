"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every Pallas kernel in this package has an exact pure-jnp twin here; pytest
asserts allclose between the two across shape/dtype sweeps (hypothesis) and
the fixed TinyMoE shapes that the AOT pipeline lowers.
"""

import jax.numpy as jnp


def silu(x):
    """SiLU / swish activation: x * sigmoid(x)."""
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def expert_ffn_ref(x, w1, w2, w3):
    """SwiGLU expert FFN: (silu(x @ w1) * (x @ w3)) @ w2.

    Args:
      x:  [C, D] tokens routed to this expert (rows of zeros are inert).
      w1: [D, F] gate projection.
      w2: [F, D] down projection.
      w3: [D, F] up projection.
    Returns:
      [C, D] expert output.
    """
    h = silu(x @ w1) * (x @ w3)
    return h @ w2


def topk_gate_ref(x, wg, k):
    """Fused gate: softmax(x @ wg), keep top-k per row, renormalize.

    Ties are broken deterministically toward the lower expert index by
    subtracting ``index * 1e-7`` from the probabilities before thresholding
    (the Pallas kernel uses the identical tie-break, so the two are exactly
    comparable).

    Args:
      x:  [N, D] flattened token hidden states (post pre-MoE layernorm).
      wg: [D, E] gate projection.
      k:  number of experts to keep per token.
    Returns:
      [N, E] routing weight matrix; exactly k nonzeros per row, each row
      sums to 1.
    """
    logits = x @ wg
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    exp = jnp.exp(logits)
    probs = exp / jnp.sum(exp, axis=-1, keepdims=True)
    e = probs.shape[-1]
    tb = probs - jnp.arange(e, dtype=probs.dtype) * jnp.asarray(1e-7, probs.dtype)
    kth = jnp.sort(tb, axis=-1)[..., e - k][..., None]
    mask = (tb >= kth).astype(probs.dtype)
    w = probs * mask
    return w / jnp.sum(w, axis=-1, keepdims=True)
