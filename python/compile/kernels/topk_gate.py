"""Pallas kernel: fused gate projection + softmax + top-k + renormalize (L1).

The gate is the routing hot-spot of every MoE layer: for each token it
produces the sparse expert weight row the coordinator routes on. Fusing the
[N,D]x[D,E] projection, the row softmax, the top-k mask, and the
renormalization into one kernel keeps the [block_n, E] logits tile in VMEM
end-to-end — the paper's all-to-all dispatch then consumes only the final
sparse weight matrix.

Deterministic tie-break (lower expert index wins) makes the kernel exactly
comparable to ``ref.topk_gate_ref`` and to the Rust coordinator's routing
view of the output. ``interpret=True`` per the CPU-PJRT constraint.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gate_kernel(x_ref, wg_ref, o_ref, *, k):
    x = x_ref[...]
    logits = x @ wg_ref[...]
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    exp = jnp.exp(logits)
    probs = exp / jnp.sum(exp, axis=-1, keepdims=True)
    e = probs.shape[-1]
    # Tie-break toward the lower expert index, then threshold on the k-th
    # largest tie-broken probability per row.
    tb = probs - jnp.arange(e, dtype=probs.dtype) * jnp.asarray(1e-7, probs.dtype)
    kth = jnp.sort(tb, axis=-1)[..., e - k][..., None]
    mask = (tb >= kth).astype(probs.dtype)
    w = probs * mask
    o_ref[...] = w / jnp.sum(w, axis=-1, keepdims=True)


def _pick_block(n):
    b = 1
    while b < 128 and n % (b * 2) == 0:
        b *= 2
    return min(b, n)


@functools.partial(jax.jit, static_argnames=("k", "block_n"))
def topk_gate(x, wg, k, block_n=None):
    """Routing weights for a flattened token batch via a Pallas kernel.

    Args:
      x:  [N, D] token hidden states (post pre-MoE layernorm).
      wg: [D, E] gate projection.
      k:  experts kept per token (static).
      block_n: token-tile height; must divide N. Default: auto.
    Returns:
      [N, E] routing weights; exactly k nonzeros per row summing to 1.
    """
    n, d = x.shape
    e = wg.shape[1]
    bn = block_n or _pick_block(n)
    assert n % bn == 0, f"block_n={bn} must divide N={n}"
    return pl.pallas_call(
        functools.partial(_gate_kernel, k=k),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, e), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bn, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, e), x.dtype),
        interpret=True,
    )(x, wg)
