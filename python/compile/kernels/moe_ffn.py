"""Pallas kernel: grouped SwiGLU expert FFN (the MoE compute hot-spot, L1).

TPU mapping of the paper's per-expert CUDA GEMMs (DESIGN.md
§Hardware-Adaptation): each serverless expert instance processes a dense
``[cap, d_model]`` tile of routed tokens. The kernel tiles the token
dimension for VMEM, keeps the SwiGLU intermediate ``h = silu(x@w1) * (x@w3)``
resident in VMEM (never spilled to HBM), and streams the second GEMM
``h @ w2`` through the same scratch. Weights use whole-matrix BlockSpecs —
at TinyMoE scale (D=64, F=256, f32) the full working set is ~0.3 MB, far
under the 16 MB/core VMEM budget; the block shapes below keep the same
schedule valid at Mixtral scale with bf16 + 128-row token tiles.

``interpret=True`` is mandatory: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute. Correctness is asserted
against ``ref.expert_ffn_ref`` by pytest; TPU perf is estimated analytically
(DESIGN.md §Perf), never from interpret-mode wallclock.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, w2_ref, w3_ref, o_ref):
    """One token-tile of the SwiGLU FFN: o = (silu(x@w1) * (x@w3)) @ w2."""
    x = x_ref[...]
    w1 = w1_ref[...]
    w3 = w3_ref[...]
    # Fused SwiGLU: the [block_c, F] intermediate lives only in VMEM.
    a = x @ w1
    h = (a * (1.0 / (1.0 + jnp.exp(-a)))) * (x @ w3)
    o_ref[...] = h @ w2_ref[...]


def _pick_block(c):
    """Token-tile size: largest power-of-two divisor of c, capped at 128.

    128 rows is the MXU-friendly tile height; smaller inputs collapse to a
    single tile.
    """
    b = 1
    while b < 128 and c % (b * 2) == 0:
        b *= 2
    return min(b, c)


@functools.partial(jax.jit, static_argnames=("block_c",))
def expert_ffn(x, w1, w2, w3, block_c=None):
    """SwiGLU expert FFN over a dense token tile via a Pallas kernel.

    Args:
      x:  [C, D] routed tokens (zero rows are inert: ffn(0) == 0).
      w1: [D, F] gate projection.
      w2: [F, D] down projection.
      w3: [D, F] up projection.
      block_c: token-tile height; must divide C. Default: auto.
    Returns:
      [C, D] expert output, same dtype as x.
    """
    c, d = x.shape
    f = w1.shape[1]
    bc = block_c or _pick_block(c)
    assert c % bc == 0, f"block_c={bc} must divide C={c}"
    grid = (c // bc,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, d), lambda i: (i, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
            pl.BlockSpec((f, d), lambda i: (0, 0)),
            pl.BlockSpec((d, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bc, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((c, d), x.dtype),
        interpret=True,
    )(x, w1, w2, w3)
