"""L1 correctness: Pallas kernels vs pure-jnp oracles (the core signal).

Fixed-shape cases cover the exact TinyMoE shapes the AOT pipeline lowers;
hypothesis sweeps shapes/dtypes/k per the session's testing contract.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.moe_ffn import expert_ffn, _pick_block
from compile.kernels.topk_gate import topk_gate


def _rand(key, shape, dtype=jnp.float32, scale=1.0):
    return jax.random.normal(key, shape, dtype) * scale


# ---------------------------------------------------------------------------
# expert_ffn
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("c,d,f", [(64, 64, 256), (32, 64, 256), (128, 64, 256),
                                   (16, 8, 32), (1, 4, 8), (256, 32, 64)])
def test_ffn_matches_ref_fixed(c, d, f):
    ks = jax.random.split(jax.random.PRNGKey(c + d + f), 4)
    x = _rand(ks[0], (c, d))
    w1, w2, w3 = _rand(ks[1], (d, f), scale=0.1), _rand(ks[2], (f, d), scale=0.1), _rand(ks[3], (d, f), scale=0.1)
    y = expert_ffn(x, w1, w2, w3)
    np.testing.assert_allclose(y, ref.expert_ffn_ref(x, w1, w2, w3), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_c", [1, 2, 8, 16, 64])
def test_ffn_block_sizes_equivalent(block_c):
    """Tiling must not change numerics: every valid block_c agrees."""
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    x = _rand(ks[0], (64, 16))
    w1, w2, w3 = _rand(ks[1], (16, 32)), _rand(ks[2], (32, 16)), _rand(ks[3], (16, 32))
    base = expert_ffn(x, w1, w2, w3, block_c=64)
    np.testing.assert_allclose(
        expert_ffn(x, w1, w2, w3, block_c=block_c), base, rtol=1e-4, atol=1e-4
    )


def test_ffn_zero_rows_inert():
    """Capacity padding contract: ffn(0-row) == 0, so pad slots never leak."""
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = _rand(ks[0], (8, 16)).at[5:].set(0.0)
    w1, w2, w3 = _rand(ks[1], (16, 32)), _rand(ks[2], (32, 16)), _rand(ks[3], (16, 32))
    y = expert_ffn(x, w1, w2, w3)
    np.testing.assert_allclose(y[5:], jnp.zeros((3, 16)), atol=1e-7)


def test_ffn_row_independence():
    """Row i of the output depends only on row i of the input (routing
    soundness: gathered execution == dense execution)."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x = _rand(ks[0], (16, 8))
    w1, w2, w3 = _rand(ks[1], (8, 16)), _rand(ks[2], (16, 8)), _rand(ks[3], (8, 16))
    full = expert_ffn(x, w1, w2, w3)
    perm = jax.random.permutation(ks[4], 16)
    shuffled = expert_ffn(x[perm], w1, w2, w3)
    np.testing.assert_allclose(shuffled, full[perm], rtol=1e-5, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    c=st.sampled_from([1, 2, 4, 8, 16, 32, 64]),
    d=st.sampled_from([4, 8, 16, 64]),
    f=st.sampled_from([8, 16, 32, 256]),
    seed=st.integers(0, 2**16),
)
def test_ffn_hypothesis_shapes(c, d, f, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (c, d))
    w1, w2, w3 = _rand(ks[1], (d, f), scale=0.2), _rand(ks[2], (f, d), scale=0.2), _rand(ks[3], (d, f), scale=0.2)
    y = expert_ffn(x, w1, w2, w3)
    np.testing.assert_allclose(y, ref.expert_ffn_ref(x, w1, w2, w3), rtol=1e-4, atol=1e-5)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_ffn_bf16(seed):
    """bf16 path (the MXU dtype): kernel matches ref at bf16 tolerance."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = _rand(ks[0], (32, 16), jnp.bfloat16)
    w1 = _rand(ks[1], (16, 32), jnp.bfloat16, 0.2)
    w2 = _rand(ks[2], (32, 16), jnp.bfloat16, 0.2)
    w3 = _rand(ks[3], (16, 32), jnp.bfloat16, 0.2)
    y = expert_ffn(x, w1, w2, w3).astype(jnp.float32)
    r = ref.expert_ffn_ref(x, w1, w2, w3).astype(jnp.float32)
    np.testing.assert_allclose(y, r, rtol=0.1, atol=0.1)


def test_pick_block():
    assert _pick_block(1) == 1
    assert _pick_block(64) == 64
    assert _pick_block(128) == 128
    assert _pick_block(256) == 128
    assert _pick_block(96) == 32
    assert _pick_block(3) == 1


# ---------------------------------------------------------------------------
# topk_gate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,d,e,k", [(128, 64, 8, 2), (64, 64, 16, 2),
                                     (32, 16, 8, 1), (16, 8, 4, 4), (8, 8, 8, 8)])
def test_gate_matches_ref(n, d, e, k):
    ks = jax.random.split(jax.random.PRNGKey(n + e + k), 2)
    x, wg = _rand(ks[0], (n, d)), _rand(ks[1], (d, e), scale=0.5)
    g = topk_gate(x, wg, k)
    np.testing.assert_allclose(g, ref.topk_gate_ref(x, wg, k), rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("k", [1, 2, 3])
def test_gate_exactly_k_nonzero_rowsum_one(k):
    ks = jax.random.split(jax.random.PRNGKey(k), 2)
    x, wg = _rand(ks[0], (64, 16)), _rand(ks[1], (16, 8), scale=0.5)
    g = np.asarray(topk_gate(x, wg, k))
    assert ((g > 0).sum(axis=1) == k).all()
    np.testing.assert_allclose(g.sum(axis=1), np.ones(64), rtol=1e-5)


def test_gate_tie_break_low_index():
    """Identical logits (wg == 0): deterministic lower-index winners."""
    x = jnp.ones((4, 8))
    wg = jnp.zeros((8, 4))
    g = np.asarray(topk_gate(x, wg, 2))
    assert (g[:, :2] > 0).all() and (g[:, 2:] == 0).all()
    r = np.asarray(ref.topk_gate_ref(x, wg, 2))
    np.testing.assert_allclose(g, r, atol=1e-6)


@settings(max_examples=12, deadline=None)
@given(
    n=st.sampled_from([1, 2, 8, 32, 128]),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
    seed=st.integers(0, 2**16),
)
def test_gate_hypothesis(n, e, k, seed):
    k = min(k, e)
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x, wg = _rand(ks[0], (n, 16)), _rand(ks[1], (16, e), scale=0.5)
    g = topk_gate(x, wg, k)
    np.testing.assert_allclose(g, ref.topk_gate_ref(x, wg, k), rtol=1e-4, atol=1e-6)
    gn = np.asarray(g)
    assert ((gn > 0).sum(axis=1) == k).all()
