"""Unit tests for the predictor fine-tuning pipeline (metrics + training)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import finetune as F
from compile import model as M


def test_topk_sets():
    w = np.array([[0.1, 0.7, 0.0, 0.2], [0.9, 0.0, 0.05, 0.05]])
    np.testing.assert_array_equal(F.topk_sets(w, 2), [[1, 3], [0, 2]])


def test_topk_overlap_acc_bounds():
    w = np.random.default_rng(0).random((64, 8))
    assert F.topk_overlap_acc(w, w, 2) == 1.0
    disjoint_pred = np.zeros((4, 8))
    disjoint_pred[:, :2] = 1.0
    disjoint_act = np.zeros((4, 8))
    disjoint_act[:, 6:] = 1.0
    assert F.topk_overlap_acc(disjoint_pred, disjoint_act, 2) == 0.0


def test_load_pearson_perfect():
    rng = np.random.default_rng(1)
    w = rng.random((256, 8))
    r, pts = F.load_pearson(w, w, 2, group=128)
    assert r > 0.999
    assert len(pts) == 16
    # Each group's loads sum to group * k.
    for s in range(0, 16, 8):
        assert sum(a for _, a in pts[s : s + 8]) == 128 * 2


def test_mean_cosine():
    a = np.array([[1.0, 0.0], [0.0, 2.0]])
    assert abs(F.mean_cosine(a, a) - 1.0) < 1e-6
    b = np.array([[0.0, 1.0], [2.0, 0.0]])
    assert abs(F.mean_cosine(a, b)) < 1e-6


def test_adam_reduces_kl():
    """Fine-tuning a gate replica on synthetic data reduces the KL loss."""
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (512, 16))
    wg_true = jax.random.normal(k2, (16, 8)) * 0.5
    target = jax.nn.softmax(x @ wg_true, axis=-1)
    wg0 = jax.random.normal(k3, (16, 8)) * 0.5
    before = float(F.kl_to_actual(wg0, x, target))
    wg = F.adam_train(F.kl_to_actual, wg0, (x, target), steps=150, lr=5e-3,
                      batch=128, seed=0)
    after = float(F.kl_to_actual(wg, x, target))
    assert after < before * 0.7


def test_collect_dataset_shapes():
    cfg = M.TinyMoEConfig(n_layers=2)
    params = M.init_params(cfg, seed=0)
    moe_ins, routes = F.collect_dataset(cfg, params, n_batches=2, seed=1)
    n = 2 * cfg.n_tokens
    assert len(moe_ins) == 2 and len(routes) == 2
    assert moe_ins[0].shape == (n, cfg.d_model)
    assert routes[0].shape == (n, cfg.n_experts)
    assert ((routes[0] > 0).sum(axis=1) == cfg.top_k).all()
