"""L2 correctness: TinyMoE monolithic vs decomposed execution.

``test_decomposed_equals_monolithic`` emulates exactly what the Rust
coordinator does per layer — gate -> gather routed tokens into capacity
tiles -> per-expert-instance Pallas FFN -> weighted scatter + residual —
and asserts the logits match the monolithic forward. This pins the ABI the
Rust e2e test then re-verifies over real PJRT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.TinyMoEConfig()


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    k1, k2 = jax.random.split(jax.random.PRNGKey(42))
    tokens = jax.random.randint(k1, (CFG.batch, CFG.seq), 0, CFG.vocab, jnp.int32)
    lens = jax.random.randint(k2, (CFG.batch,), CFG.seq // 2, CFG.seq + 1)
    len_mask = (jnp.arange(CFG.seq)[None, :] < lens[:, None]).astype(jnp.float32)
    return tokens, len_mask


def test_forward_shapes(params, batch):
    tokens, len_mask = batch
    logits = M.forward(CFG, params, tokens, len_mask)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_forward_deterministic(params, batch):
    tokens, len_mask = batch
    a = M.forward(CFG, params, tokens, len_mask)
    b = M.forward(CFG, params, tokens, len_mask)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_param_specs_cover_params(params):
    specs = CFG.param_specs()
    assert set(n for n, _ in specs) == set(params.keys())
    for name, shape in specs:
        assert params[name].shape == shape, name


def _decomposed_forward(cfg, params, tokens, len_mask):
    """Python twin of the Rust serving path (gather/scatter in numpy)."""
    x = M.embed_fn(cfg, tokens, params["wemb"], params["wpos"])
    b, t, d = x.shape
    cap = cfg.capacity
    for l in range(cfg.n_layers):
        p = f"layer{l}."
        h, moe_in = M.attn_fn(
            cfg, x, len_mask,
            params[p + "ln1.g"], params[p + "ln1.b"],
            params[p + "wq"], params[p + "wk"], params[p + "wv"], params[p + "wo"],
            params[p + "ln2.g"], params[p + "ln2.b"],
        )
        weights = np.asarray(M.gate_fn(cfg, moe_in, params[p + "wg"]))
        moe_np = np.asarray(moe_in)
        out = np.zeros_like(moe_np)
        for e in range(cfg.n_experts):
            rows = np.nonzero(weights[:, e] > 0)[0]
            # Replica fan-out: each serverless instance takes <= cap tokens.
            for s in range(0, len(rows), cap):
                sub = rows[s : s + cap]
                tile = np.zeros((cap, d), np.float32)
                tile[: len(sub)] = moe_np[sub]
                y = np.asarray(M.expert_fn(
                    cfg, jnp.asarray(tile),
                    params[p + "w1"][e], params[p + "w2"][e], params[p + "w3"][e],
                ))
                out[sub] += weights[sub, e : e + 1] * y[: len(sub)]
        x = h + jnp.asarray(out).reshape(b, t, d)
    return M.head_fn(cfg, x, params["lnf.g"], params["lnf.b"], params["whead"])


def test_decomposed_equals_monolithic(params, batch):
    tokens, len_mask = batch
    mono = np.asarray(M.forward(CFG, params, tokens, len_mask))
    deco = np.asarray(_decomposed_forward(CFG, params, tokens, len_mask))
    np.testing.assert_allclose(deco, mono, rtol=1e-4, atol=1e-4)


def test_intermediates_consistent(params, batch):
    tokens, len_mask = batch
    logits, moe_ins, routes = M.forward_with_intermediates(CFG, params, tokens, len_mask)
    mono = M.forward(CFG, params, tokens, len_mask)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(mono), rtol=1e-5, atol=1e-5)
    assert len(moe_ins) == CFG.n_layers and len(routes) == CFG.n_layers
    for mi, w in zip(moe_ins, routes):
        assert mi.shape == (CFG.n_tokens, CFG.d_model)
        assert w.shape == (CFG.n_tokens, CFG.n_experts)
        wn = np.asarray(w)
        assert ((wn > 0).sum(axis=1) == CFG.top_k).all()
        np.testing.assert_allclose(wn.sum(axis=1), 1.0, rtol=1e-4)


def test_routing_is_skewed(params, batch):
    """Sanity: real gates produce non-uniform expert popularity (Fig. 1's
    premise — the phenomenon MoEless exists to fix)."""
    tokens, len_mask = batch
    _, _, routes = M.forward_with_intermediates(CFG, params, tokens, len_mask)
    loads = np.stack([(np.asarray(w) > 0).sum(axis=0) for w in routes])
    cv = loads.std(axis=1) / loads.mean(axis=1)
    assert (cv > 0.05).any(), f"expected skew, got CV={cv}"
