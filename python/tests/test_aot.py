"""AOT pipeline: emitted HLO artifacts, manifest ABI, weight binary layout."""

import json
import os

import numpy as np
import pytest

from compile import model as M
from compile.aot import lower_artifacts, to_hlo_text

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
CFG = M.TinyMoEConfig()

EXPECTED = ["tiny_model", "tiny_embed", "tiny_attn", "tiny_gate",
            "tiny_expert", "tiny_head"]


def _have_artifacts():
    return os.path.exists(os.path.join(ART, "manifest.json"))


def test_lowering_emits_hlo_text():
    """Lower a small component fresh; the output must be parseable HLO text
    (``HloModule`` header), not a serialized proto."""
    arts = {}
    import jax

    lowered = jax.jit(lambda x, wg: M.gate_fn(CFG, x, wg)).lower(
        jax.ShapeDtypeStruct((CFG.n_tokens, CFG.d_model), np.float32),
        jax.ShapeDtypeStruct((CFG.d_model, CFG.n_experts), np.float32),
    )
    text = to_hlo_text(lowered)
    assert text.startswith("HloModule")
    assert "ROOT" in text


@pytest.mark.skipif(not _have_artifacts(), reason="run `make artifacts` first")
class TestEmittedArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            return json.load(f)

    def test_all_artifacts_present(self, manifest):
        for name in EXPECTED:
            assert name in manifest["artifacts"]
            path = os.path.join(ART, manifest["artifacts"][name]["file"])
            assert os.path.exists(path), path
            with open(path) as f:
                head = f.read(64)
            assert head.startswith("HloModule"), name

    def test_tensor_table_matches_bin(self, manifest):
        size = os.path.getsize(os.path.join(ART, "weights.bin"))
        end = 0
        for name, t in manifest["tensors"].items():
            n = int(np.prod(t["shape"])) if t["shape"] else 1
            assert t["nbytes"] == n * 4, name
            end = max(end, t["offset"] + t["nbytes"])
        assert end == size

    def test_manifest_param_order_is_spec_order(self, manifest):
        spec_names = [n for n, _ in CFG.param_specs()]
        mono = manifest["artifacts"]["tiny_model"]["weight_params"]
        assert [p["name"] for p in mono] == spec_names

    def test_weights_roundtrip(self, manifest):
        """weights.bin re-read at manifest offsets == init_params output."""
        params = M.init_params(CFG, seed=manifest["model"]["seed"])
        blob = open(os.path.join(ART, "weights.bin"), "rb").read()
        for name in ["wemb", "layer0.wg", "layer3.w2", "whead"]:
            t = manifest["tensors"][name]
            arr = np.frombuffer(
                blob[t["offset"] : t["offset"] + t["nbytes"]], np.float32
            ).reshape(t["shape"])
            np.testing.assert_array_equal(arr, np.asarray(params[name]))

    def test_expert_abi_shapes(self, manifest):
        abi = manifest["artifacts"]["tiny_expert"]
        assert abi["weight_scope"] == "expert"
        ri = abi["runtime_inputs"][0]
        assert ri["shape"] == [CFG.capacity, CFG.d_model]

    def test_gate_and_predictor_share_abi(self, manifest):
        """The predictor is a gate replica: same artifact, different weights."""
        abi = manifest["artifacts"]["tiny_gate"]
        assert abi["weight_scope"] == "layer"
        assert abi["weight_params"][0]["shape"] == [CFG.d_model, CFG.n_experts]


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "predictor_profile.json")),
    reason="run `make artifacts` first",
)
class TestPredictorArtifacts:
    @pytest.fixture(scope="class")
    def profile(self):
        with open(os.path.join(ART, "predictor_profile.json")) as f:
            return json.load(f)

    def test_profile_covers_all_layer_distance_pairs(self, profile):
        pairs = {(e["layer"], e["distance"]) for e in profile["entries"]}
        want = {(l, d) for l in range(CFG.n_layers)
                for d in range(1, CFG.n_layers - l)}
        assert pairs == want

    def test_finetune_never_hurts(self, profile):
        for e in profile["entries"]:
            assert e["acc_finetuned"] >= e["acc_pretrained"] - 0.02, e

    def test_layer_awareness(self, profile):
        h = profile["threshold"]
        for e in profile["entries"]:
            assert e["finetuned"] == (e["acc_pretrained"] < h)

    def test_predictor_tensors_exist(self, profile):
        size = os.path.getsize(os.path.join(ART, "predictors.bin"))
        for name, t in profile["tensors"].items():
            assert name.startswith("pred.l")
            assert t["shape"] == [CFG.d_model, CFG.n_experts]
            assert t["offset"] + t["nbytes"] <= size

    def test_footprint_ratio(self, profile):
        """Ours == Mixtral-offloading footprint; ProMoE substantially larger
        (Table 2's shape)."""
        f = profile["footprints_bytes"]
        assert f["ours_per_predictor"] == f["mixtral_offloading_per_predictor"]
        assert f["promoe_per_predictor"] > 5 * f["ours_per_predictor"]
